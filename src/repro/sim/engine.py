"""Discrete-event simulator for edge orchestration (paper §V).

Reproduces the paper's evaluation protocol: per 15 s cycle, ~1000
application instances arrive clustered inside the first 1.5 s; 100 edge
devices (uniform over the 8 Table-III classes) serve them; devices leave the
network permanently at exponentially-distributed lifetimes (Table IV rates)
*without announcing it* — a task lands on a departed device simply fails at
its estimated completion time.

Ground truth execution times follow the same linear interference law the
orchestrator was profiled with (Eq. 1) — evaluated with the *actual*
co-located task counts at start — times multiplicative log-normal noise.
T_alloc bookkeeping mirrors the paper: provisional intervals are recorded at
placement and replaced by actual intervals when tasks really start.

Placement goes through the pure two-phase protocol: each arrival is planned
with ``orchestrate(app, cluster, t, policy)`` and made real with
``cluster.apply(plan)`` — the engine never calls a mutating ``place``.
Prefer driving the engine through :class:`repro.api.Orchestrator`
(``submit`` / ``step`` / ``drain``).

Stage barrier: tasks of stage i+1 start only once every stage-i task has
completed (Algorithm 1 line 44).  A task completes when any replica
succeeds; what happens when a task's LAST replica dies is the recovery
strategy's call (:mod:`repro.core.recovery`): ``fail_fast`` fails the
instance immediately (Eq. 4, the bit-identical default), ``failover``
restarts the task on the best surviving device after a detection delay,
``replan`` re-invokes the placement policy on the live sub-fleet.

Churn runtime: pass a :class:`repro.sim.churn.ChurnSchedule` and the engine
processes DEVICE_DOWN / DEVICE_UP events — a departing device kills its
in-flight replicas on the spot (their remaining T_alloc occupancy is
returned) and is masked out of every later placement's feasibility; a
rejoining device comes back empty (fresh join time, cold model cache) and
is re-admitted as placement capacity.

Partial-result salvage: with ``salvage > 0``, an instance about to be
declared lost (its recovery strategy gave up, or ``fail_fast`` fired) is
re-submitted instead of discarded when it has completed stages to show for
itself: the completed tasks' placements are pinned through the pure
``orchestrate(pinned=...)`` substrate — so their outputs' transfer costs
keep being priced from the devices that hold them — and only the unfinished
remainder is re-planned and restarted.  Completed stages are NEVER re-run.
"""
from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from ..core.cluster import ClusterState
from ..core.dag import AppDAG
from ..core.orchestrator import Placement, Replica, orchestrate
from ..core.policy import Policy, make_policy
from ..core.recovery import RecoveryStrategy, make_recovery
from ..obs.metrics import EngineStats
from ..obs.tracing import FLEET_TID, Tracer

__all__ = ["InstanceRecord", "SimResult", "Engine"]


@dataclass
class InstanceRecord:
    app: str
    arrival: float
    finished: float = float("nan")
    failed: bool = False
    service_time: float = float("nan")
    n_tasks: int = 0
    n_replicas: int = 0
    pred_latency: float = float("nan")
    pred_fail: float = float("nan")
    # trace id in the engine's Tracer (-1 = tracing disabled)
    tid: int = -1


@dataclass
class SimResult:
    scheme: str
    scenario: str
    instances: List[InstanceRecord]
    load_per_device: np.ndarray          # tasks executed per device
    horizon: float
    # attached extras: the StreamResult (scenario "stream") and the span
    # trace (SimConfig(trace=True)); None when the feature is off.
    stream: Optional[object] = None
    trace: Optional[Tracer] = None

    # -- paper metrics (§V-E) ---------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.instances)

    @property
    def prob_failure(self) -> float:
        if not self.instances:
            return 0.0
        return float(np.mean([r.failed for r in self.instances]))

    @property
    def avg_service_time(self) -> float:
        ok = [r.service_time for r in self.instances if not r.failed]
        return float(np.mean(ok)) if ok else float("nan")

    def per_app(self) -> Dict[str, Tuple[float, float]]:
        """app name -> (avg service time, prob failure)."""
        out: Dict[str, Tuple[float, float]] = {}
        for name in sorted({r.app for r in self.instances}):
            rs = [r for r in self.instances if r.app == name]
            ok = [r.service_time for r in rs if not r.failed]
            out[name] = (
                float(np.mean(ok)) if ok else float("nan"),
                float(np.mean([r.failed for r in rs])),
            )
        return out


@dataclass
class _AppRun:
    rec: InstanceRecord
    app: AppDAG
    placement: Placement
    # The plan's own timestamp: ``ClusterState.apply`` recorded every
    # provisional interval at ``plan.now + est_start``, so cancellation MUST
    # use the same origin.  For fused waves planned against one snapshot,
    # ``plan.now`` can differ from the arrival event time — cancelling at
    # ``rec.arrival + est_start`` would leave ghost T_alloc residue.
    plan_now: float = 0.0
    stage_idx: int = 0
    stage_pending: int = 0
    # task -> #replicas still in flight (None once task resolved)
    inflight: Dict[str, int] = field(default_factory=dict)
    done: Dict[str, bool] = field(default_factory=dict)
    started: set = field(default_factory=set)
    failed: bool = False
    # -- churn / recovery state ------------------------------------------------
    # replica ids of this instance still executing (engine._active keys)
    live_rids: Set[int] = field(default_factory=set)
    # per-task provisional-interval origin: a replanned task's occupancy was
    # re-recorded by apply at ITS plan's timestamp, not the original one
    origins: Dict[str, float] = field(default_factory=dict)
    # per-task recovery attempts consumed (failover / replan budgets)
    retries: Dict[str, int] = field(default_factory=dict)
    # a replica of this instance died at some point (recovered-vs-lost stats)
    touched: bool = False
    # -- partial-result salvage -------------------------------------------------
    # salvage resubmissions consumed (bounded by Engine.salvage)
    salvages: int = 0
    # bumped on every salvage so RECOVER events scheduled for the doomed
    # pre-salvage placement are dropped instead of double-restarting tasks
    epoch: int = 0


class Engine:
    """Runs one (scheduler, scenario) simulation."""

    ARRIVAL = 0
    TASK_END = 1
    DEVICE_DOWN = 2
    DEVICE_UP = 3
    RECOVER = 4

    def __init__(
        self,
        cluster: ClusterState,
        scheduler,
        seed: int = 0,
        noise_sigma: float = 0.10,
        churn=None,
        recovery="fail_fast",
        salvage: int = 0,
        track_intervals: bool = False,
        trace: Optional[Tracer] = None,
    ):
        """``scheduler`` may be a pure :class:`~repro.core.policy.Policy` or
        a registered policy name — every placement is routed through
        ``orchestrate`` + ``cluster.apply``.

        ``churn`` is an optional :class:`repro.sim.churn.ChurnSchedule`;
        installing one makes the schedule the single source of truth for
        device lifetimes (DEVICE_DOWN / DEVICE_UP events drive departures
        and rejoins).  ``recovery`` names a registered
        :class:`~repro.core.recovery.RecoveryStrategy` (or passes an
        instance); the default ``fail_fast`` is bit-identical to the
        pre-churn engine.  ``salvage`` bounds per-instance partial-result
        salvage resubmissions (0 = off, the bit-identical default): a lost
        instance with completed stages is re-planned through
        ``orchestrate(pinned=...)`` instead of discarded.
        ``track_intervals`` records every replica's
        actual execution span in :attr:`executed` so tests can prove the
        occupancy bookkeeping nets to exactly the executed work.
        ``trace`` takes a :class:`repro.obs.tracing.Tracer`: every
        instance then gets a structured span trace (admission -> plan ->
        per-replica exec -> recovery -> terminal outcome), sim-clock
        timestamped; None (the default) emits nothing and costs one
        ``is not None`` check per event."""
        self.cluster = cluster
        if isinstance(scheduler, str):
            scheduler = make_policy(scheduler, seed=seed)
        self.policy: Policy = scheduler
        self.recovery: RecoveryStrategy = (
            make_recovery(recovery) if isinstance(recovery, str) else recovery
        )
        self.noise = np.random.default_rng(seed + 17)
        self.noise_sigma = noise_sigma
        self.events: List[Tuple[float, int, int, tuple]] = []
        self._seq = itertools.count()
        self.records: List[InstanceRecord] = []
        self.load = np.zeros(cluster.n_devices, dtype=np.int64)
        self.now = 0.0
        # in-flight replica registry: rid -> (run, tname, did, ttype, t0, t1)
        self._active: Dict[int, tuple] = {}
        self._dev_active: List[Set[int]] = [set() for _ in cluster.devices]
        self._rid = itertools.count()
        self.track_intervals = track_intervals
        # (did, ttype, t0, t1, t_cut) actual execution spans; t_cut < t1
        # marks a replica killed mid-flight (its tail occupancy returned)
        self.executed: List[Tuple[int, int, float, float, float]] = []
        self.replan_time = 0.0
        self.salvage = int(salvage)
        # Conservation ledger: every instance the engine takes accounting
        # responsibility for lands in exactly one terminal bucket —
        #   admitted == completed + lost + shed
        # ("shed" is charged by the stream admission layer, which counts a
        # shed arrival as admitted-and-shed; pure engine runs keep it 0).
        # ``drain`` asserts the identity.  EngineStats is typed over the
        # frozen ENGINE_COUNTERS vocabulary: a misspelled counter raises
        # AttributeError instead of silently minting a new key.
        self.stats = EngineStats()
        self.trace = trace
        # rid -> open "exec" span id, populated only when tracing
        self._span_of: Dict[int, int] = {}
        self.churn = churn or None      # False (churn forced off) == None
        if self.churn is not None:
            churn.install(cluster)
            for ev in churn.events:
                kind = self.DEVICE_DOWN if ev.kind == "leave" else self.DEVICE_UP
                self._push(ev.t, kind, (ev.did, ev.until))

    # -- event helpers ----------------------------------------------------------
    def _push(self, t: float, kind: int, payload: tuple) -> None:
        heapq.heappush(self.events, (t, next(self._seq), kind, payload))

    def add_arrivals(
        self,
        apps: List[AppDAG],
        times: List[float],
        plans: Optional[List] = None,
    ) -> None:
        """Enqueue arrivals.  ``plans`` (from ``orchestrate_batch``) carries
        pre-computed placements for the fused burst path; without it each
        arrival is planned when its event fires."""
        if plans is None:
            plans = [None] * len(apps)
        for app, t, plan in zip(apps, times, plans):
            self._push(t, self.ARRIVAL, (app, plan))

    # -- task lifecycle -----------------------------------------------------------
    def _start_stage(self, run: _AppRun) -> None:
        app, placement = run.app, run.placement
        while run.stage_idx < app.n_stages:
            stage = app.stages[run.stage_idx]
            # done tasks are skipped: after a salvage resubmission earlier
            # stages are complete (pinned) and must never re-run
            todo = [
                t for t in stage
                if t in placement.tasks and not run.done.get(t, False)
            ]
            if todo:
                run.stage_pending = len(todo)
                for tname in todo:
                    self._start_task(run, tname)
                return
            run.stage_idx += 1
        # no runnable stage left -> app complete
        self._finish_app(run, failed=False)

    def _start_task(self, run: _AppRun, tname: str) -> None:
        cluster = self.cluster
        tp = run.placement.tasks[tname]
        spec = run.app.tasks[tname]
        run.inflight[tname] = 0
        run.started.add(tname)
        prov_start = run.origins.get(tname, run.plan_now) + tp.est_start
        for rep in tp.replicas:
            # Replace the provisional T_alloc interval with the actual one.
            cluster.add_interval(
                rep.did, spec.ttype, prov_start, prov_start + rep.est_total, w=-1.0
            )
            self._launch_replica(run, tname, rep)

    def _launch_replica(self, run: _AppRun, tname: str, rep: Replica) -> None:
        """Start one replica NOW: ground-truth duration from the actual
        co-located counts (Eq. 1 + noise), actual T_alloc interval, and an
        entry in the in-flight registry so a device departure can kill it."""
        cluster = self.cluster
        spec = run.app.tasks[tname]
        counts = np.asarray(
            cluster.device_counts_at(rep.did, self.now), dtype=np.float64
        ).copy()
        dev = cluster.devices[rep.did]
        exec_t = cluster.model.estimate(dev.cls, spec.ttype, counts)
        if self.noise_sigma > 0:
            exec_t *= float(
                self.noise.lognormal(mean=0.0, sigma=self.noise_sigma)
            )
        dur = exec_t + rep.est_upload + rep.est_transfer
        cluster.add_interval(rep.did, spec.ttype, self.now, self.now + dur)
        self.load[rep.did] += 1
        run.inflight[tname] = run.inflight.get(tname, 0) + 1
        rid = next(self._rid)
        self._active[rid] = (
            run, tname, rep.did, spec.ttype, self.now, self.now + dur
        )
        self._dev_active[rep.did].add(rid)
        run.live_rids.add(rid)
        ok = (self.now + dur) <= dev.alive_until
        if self.trace is not None:
            tid = run.rec.tid
            # The open exec span mirrors the in-flight registry entry:
            # [t0, sched_end] is the scheduled window, the close time is
            # the actual cut (== sched_end unless churn kills it) — the
            # same triple the `executed` interval log records, which the
            # T_alloc replay property test holds the two paths to.
            self._span_of[rid] = self.trace.open_span(
                tid, "exec", self.now, name=tname,
                device=rep.did, tier=int(dev.tier), ttype=spec.ttype,
                stage=run.stage_idx, sched_end=self.now + dur,
                pred_exec=rep.est_exec, pred_upload=rep.est_upload,
                pred_transfer=rep.est_transfer, pred_fail=rep.pred_fail,
                real_exec=exec_t,
            )
            if rep.est_upload > 0:
                self.trace.add_span(
                    tid, "model_upload", self.now,
                    self.now + rep.est_upload, name=tname, device=rep.did,
                )
            if rep.est_transfer > 0:
                t0u = self.now + rep.est_upload
                self.trace.add_span(
                    tid, "parent_transfer", t0u, t0u + rep.est_transfer,
                    name=tname, device=rep.did,
                )
        self._push(self.now + dur, self.TASK_END, (run, tname, rid, ok))

    def _retire_replica(self, rid: int, info: tuple) -> None:
        """Drop one replica from the in-flight registries."""
        run, _tname, did, _ttype, _t0, _t1 = info
        self._dev_active[did].discard(rid)
        run.live_rids.discard(rid)

    def _task_end(self, run: _AppRun, tname: str, rid: int, ok: bool) -> None:
        info = self._active.pop(rid, None)
        if info is None:
            return          # replica was killed (device departure/app failure)
        self._retire_replica(rid, info)
        if self.track_intervals:
            _, _, did, ttype, t0, t1 = info
            self.executed.append((did, ttype, t0, t1, t1))
        if self.trace is not None:
            sid = self._span_of.pop(rid, None)
            if sid is not None:
                self.trace.close_span(
                    sid, info[5], outcome="ok" if ok else "dead"
                )
        if run.failed or run.done.get(tname, False):
            return
        run.inflight[tname] -= 1
        if not ok:
            run.touched = True
            self.stats.replica_deaths += 1
        if ok:
            run.done[tname] = True
            run.stage_pending -= 1
            if run.stage_pending == 0:
                run.stage_idx += 1
                self._start_stage(run)
        elif run.inflight[tname] == 0:
            # every replica failed -> the recovery strategy decides the
            # instance's fate (fail_fast == Eq. 4: fail immediately)
            self.recovery.on_task_dead(self, run, tname)

    # -- churn runtime ----------------------------------------------------------
    def _device_down(self, did: int) -> None:
        """A device departs: mask it out of future placements and kill its
        in-flight replicas on the spot — their remaining occupancy is
        returned to T_alloc and each affected task is routed through the
        recovery strategy when it just lost its last replica."""
        self.stats.device_down += 1
        self.cluster.mark_down(did, self.now)
        if self.trace is not None:
            self.trace.event(FLEET_TID, "device_down", self.now, device=did)
        # Each entry is stamped with its run's epoch AT THE POP: a salvage
        # fired by an earlier entry's recovery re-plans the run (bumping the
        # epoch) — the remaining pre-popped deaths then belong to a
        # placement that no longer exists and must not touch the relaunched
        # tasks' inflight counts (their occupancy is still returned below).
        dead: List[Tuple[int, tuple, int]] = [
            (rid, info, info[0].epoch)
            for rid, info in (
                (r, self._active.pop(r)) for r in sorted(self._dev_active[did])
            )
        ]
        for rid, info, epoch in dead:
            run, tname, _did, ttype, t0, t1 = info
            self._retire_replica(rid, info)
            self.cluster.cancel_from(did, ttype, t0, t1, self.now)
            if self.track_intervals:
                self.executed.append((did, ttype, t0, t1, self.now))
            if self.trace is not None:
                sid = self._span_of.pop(rid, None)
                if sid is not None:
                    self.trace.close_span(sid, self.now, outcome="killed")
            if (run.failed or run.done.get(tname, False)
                    or epoch != run.epoch):
                continue
            run.touched = True
            self.stats.replica_deaths += 1
            run.inflight[tname] -= 1
            if run.inflight[tname] == 0:
                self.recovery.on_task_dead(self, run, tname)

    def _device_up(self, did: int, until: float) -> None:
        """A device rejoins empty (fresh join time, cold caches) and is
        re-admitted as placement capacity until its next departure."""
        self.stats.device_up += 1
        self.cluster.mark_up(did, self.now, alive_until=until)
        if self.trace is not None:
            self.trace.event(
                FLEET_TID, "device_up", self.now, device=did, until=until
            )

    def schedule_recovery(self, run: _AppRun, tname: str, t: float) -> None:
        """Recovery-strategy hook: fire ``recovery.recover(run, tname)`` at
        absolute time ``t`` (death + detection delay).  The event carries
        the run's current epoch: a salvage resubmission in between
        invalidates it (the doomed placement it targeted no longer exists)."""
        if self.trace is not None:
            self.trace.add_span(
                run.rec.tid, "recovery_wait", self.now, t, name=tname
            )
        self._push(t, self.RECOVER, (run, tname, run.epoch))

    def _finish_app(self, run: _AppRun, failed: bool) -> None:
        if not np.isnan(run.rec.finished):
            return
        if failed and run.salvages < self.salvage and any(run.done.values()):
            if self._salvage(run):
                return                  # the instance lives on, re-planned
        if failed:
            self._cancel_running(run)
            self._cancel_provisional(run)
        run.failed = failed
        run.rec.failed = failed
        run.rec.finished = self.now
        run.rec.service_time = self.now - run.rec.arrival
        if failed:
            self.stats.lost += 1
        else:
            self.stats.completed += 1
            if run.touched:
                self.stats.recovered += 1
                if run.salvages:
                    self.stats.salvaged += 1
        if self.trace is not None and run.rec.tid >= 0:
            self.trace.end_instance(
                run.rec.tid, self.now,
                outcome="lost" if failed else "completed",
                recovered=bool(run.touched and not failed),
                salvages=run.salvages,
            )

    def _salvage(self, run: _AppRun) -> bool:
        """Partial-result salvage: instead of discarding a lost instance,
        pin its COMPLETED tasks' placements (their outputs stay where they
        were computed and keep pricing downstream transfers from those
        devices) and re-plan + restart only the unfinished remainder via the
        pure ``orchestrate(pinned=...)`` substrate.  Returns False when even
        the live sub-fleet cannot host the remainder (the instance is then
        truly lost)."""
        cluster, t = self.cluster, self.now
        run.salvages += 1
        run.epoch += 1                  # invalidate pending RECOVER events
        self.stats.salvages += 1
        # kill still-running sibling replicas and return the unstarted
        # remainder's provisional occupancy before re-planning, so the
        # salvage plan prices the fleet as it will actually be
        self._cancel_running(run)
        self._cancel_provisional(run)
        done = {k for k, v in run.done.items() if v}
        pinned = {
            k: tp for k, tp in run.placement.tasks.items() if k in done
        }
        for k in list(run.placement.tasks):
            if k not in pinned:
                del run.placement.tasks[k]
        t0 = time.perf_counter()
        plan = orchestrate(run.app, cluster, t, self.policy, pinned=pinned)
        self.replan_time += time.perf_counter() - t0
        if self.trace is not None:
            self.trace.event(
                run.rec.tid, "salvage", t,
                ok=plan.feasible, pinned=len(pinned),
            )
        if not plan.feasible:
            return False
        cluster.apply(plan)
        for k, tp in plan.placement.tasks.items():
            run.placement.tasks[k] = tp
            run.origins[k] = plan.now
        run.started = set(done)
        run.inflight = {}
        run.touched = True
        run.stage_idx = 0               # _start_stage skips completed stages
        self._start_stage(run)
        return True

    def _cancel_running(self, run: _AppRun) -> None:
        """A failed app's still-executing sibling replicas (other in-flight
        tasks of the same instance) produce output nobody will consume:
        return their unfinished occupancy so they stop distorting Eq. (1)
        estimates for everyone else."""
        for rid in sorted(run.live_rids):
            info = self._active.pop(rid, None)
            if info is None:
                continue
            _, _tname, did, ttype, t0, t1 = info
            self._dev_active[did].discard(rid)
            self.cluster.cancel_from(did, ttype, t0, t1, self.now)
            if self.track_intervals:
                self.executed.append((did, ttype, t0, t1, self.now))
            if self.trace is not None:
                sid = self._span_of.pop(rid, None)
                if sid is not None:
                    self.trace.close_span(
                        sid, self.now, outcome="cancelled"
                    )
        run.live_rids.clear()

    def _cancel_provisional(
        self, run: _AppRun, tasks: Optional[List[str]] = None
    ) -> None:
        """Remove the provisional T_alloc intervals of not-yet-started tasks
        (recorded by ``apply`` at each task's plan origin + est_start) so no
        ghost occupancy survives — on app failure (every unstarted task) or
        on a replan (the tasks about to be re-planned)."""
        cluster = self.cluster
        names = tasks if tasks is not None else list(run.placement.tasks)
        for tname in names:
            if tname in run.started:
                continue
            tp = run.placement.tasks[tname]
            spec = run.app.tasks[tname]
            start = run.origins.get(tname, run.plan_now) + tp.est_start
            for rep in tp.replicas:
                cluster.add_interval(
                    rep.did, spec.ttype, start, start + rep.est_total, w=-1.0
                )

    # -- main loop -------------------------------------------------------------
    def run(self, until: float) -> None:
        while self.events and self.events[0][0] <= until:
            t, _, kind, payload = heapq.heappop(self.events)
            self.now = t
            if kind == self.ARRIVAL:
                app, plan = payload
                # Two-phase protocol: pure planning (unless the arrival came
                # pre-planned by a fused `orchestrate_batch` wave), then the
                # one blessed mutation path (T_alloc intervals + uploads).
                if plan is None:
                    plan = orchestrate(app, self.cluster, t, self.policy)
                self.cluster.apply(plan)
                placement = plan.placement
                rec = InstanceRecord(
                    app=app.name, arrival=t, n_tasks=app.n_tasks,
                    n_replicas=placement.n_replicas(),
                    pred_latency=placement.est_latency,
                    pred_fail=placement.pred_app_fail,
                )
                self.records.append(rec)
                self.stats.admitted += 1
                if self.trace is not None:
                    rec.tid = self.trace.begin_instance(
                        app.name, t,
                        n_tasks=app.n_tasks, n_replicas=rec.n_replicas,
                    )
                    self.trace.event(
                        rec.tid, "plan", t, policy=self.policy.name,
                        pred_latency=placement.est_latency,
                        pred_fail=placement.pred_app_fail,
                        feasible=placement.feasible,
                    )
                if not placement.feasible:
                    # an infeasible arrival is an instance the fleet turned
                    # away: it is LOST the moment it arrives (previously it
                    # only set rec.failed, silently drifting the counters)
                    rec.failed = True
                    rec.finished = t
                    rec.service_time = 0.0
                    self.stats.lost += 1
                    if self.trace is not None:
                        self.trace.end_instance(
                            rec.tid, t, outcome="lost", reason="infeasible"
                        )
                    continue
                run = _AppRun(rec=rec, app=app, placement=placement,
                              plan_now=plan.now)
                self._start_stage(run)
            elif kind == self.TASK_END:
                run, tname, rid, ok = payload
                self._task_end(run, tname, rid, ok)
            elif kind == self.DEVICE_DOWN:
                self._device_down(payload[0])
            elif kind == self.DEVICE_UP:
                self._device_up(payload[0], payload[1])
            else:                                   # RECOVER
                run, tname, epoch = payload
                # stale epoch: a salvage resubmission replaced the placement
                # this recovery was scheduled against
                if (epoch == run.epoch and not run.failed
                        and not run.done.get(tname, False)):
                    self.recovery.recover(self, run, tname)
        self.now = until

    def drain(self) -> None:
        """Process every remaining event (online mode: no fixed horizon),
        then assert the conservation identity — a drained engine must have
        resolved every admitted instance into exactly one terminal bucket,
        and its in-flight replica registry must be empty (the occupancy
        analogue: nothing still holds queue capacity)."""
        while self.events:
            self.run(until=self.events[0][0])
        self.check_conservation()

    def check_conservation(self) -> None:
        """``admitted == completed + lost + shed`` (the identity itself
        lives on :class:`~repro.obs.metrics.EngineStats`, checked in one
        place) and no replica in flight.  Raises RuntimeError on drift —
        the regression guard for the counter bookkeeping."""
        self.stats.check_conservation()
        if self._active:
            raise RuntimeError(
                f"{len(self._active)} replicas still in flight after drain"
            )
        if self.trace is not None:
            self.trace.check_closed()

    def finalize(self, until: Optional[float] = None) -> None:
        """Permanently close the books: anything still unfinished counts as
        failed (the paper's cycles are long enough that this is rare).  Only
        call when the run is over — mid-run snapshots should use ``result``,
        which does NOT mutate the live records."""
        until = self.now if until is None else until
        for rec in self.records:
            if np.isnan(rec.finished):
                rec.failed = True
                rec.finished = until
                rec.service_time = until - rec.arrival
                self.stats.lost += 1
                if self.trace is not None and rec.tid >= 0:
                    self.trace.end_instance(
                        rec.tid, until, outcome="lost", reason="horizon"
                    )

    def result(self, scenario: str, horizon: float) -> SimResult:
        """Snapshot the metrics.  In-flight instances are *reported* as
        failed-at-now (the seed's horizon semantics) via per-record copies —
        the live records stay untouched, so a mid-run ``result`` followed by
        ``drain`` still yields correct final numbers."""
        from dataclasses import replace as _replace

        instances = [
            _replace(rec, failed=True, finished=self.now,
                     service_time=self.now - rec.arrival)
            if np.isnan(rec.finished) else rec
            for rec in self.records
        ]
        return SimResult(
            scheme=self.policy.name,
            scenario=scenario,
            instances=instances,
            load_per_device=self.load.copy(),
            horizon=horizon,
        )
