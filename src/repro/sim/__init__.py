"""Discrete-event edge-computing simulator reproducing the paper's §V
evaluation: device profiles (Table III/IV), the four DAG applications
(Fig. 6), the event engine, and the scheme x scenario experiment runner.
"""
from .apps import APP_BUILDERS, all_apps, lightgbm_app, mapreduce_app, matrix_app, video_app
from .churn import (
    ChurnEvent,
    ChurnSchedule,
    churn_from_monitor,
    deterministic_churn,
    exponential_churn,
    trace_churn,
)
from .engine import Engine, InstanceRecord, SimResult
from .profiles import (
    CHURN_LAMBDA_SCALE,
    DEFAULT_BACKHAUL,
    DEVICE_CLASSES,
    LAMBDA_CHURN,
    MULTI_TIER_SPECS,
    SCENARIOS,
    TASK_TYPES,
    EdgeProfile,
    TierSpec,
    make_cluster,
    make_multi_tier_cluster,
    make_profile,
)
from .runner import (
    ALL_SCHEME_NAMES,
    SCHEME_NAMES,
    SimConfig,
    make_scheduler,
    policy_for,
    run_grid,
    run_one,
    sweep_alpha,
    sweep_gamma,
)

__all__ = [
    "APP_BUILDERS",
    "all_apps",
    "lightgbm_app",
    "mapreduce_app",
    "matrix_app",
    "video_app",
    "ChurnEvent",
    "ChurnSchedule",
    "churn_from_monitor",
    "deterministic_churn",
    "exponential_churn",
    "trace_churn",
    "Engine",
    "InstanceRecord",
    "SimResult",
    "DEVICE_CLASSES",
    "CHURN_LAMBDA_SCALE",
    "LAMBDA_CHURN",
    "DEFAULT_BACKHAUL",
    "MULTI_TIER_SPECS",
    "SCENARIOS",
    "TASK_TYPES",
    "EdgeProfile",
    "TierSpec",
    "make_cluster",
    "make_multi_tier_cluster",
    "make_profile",
    "SCHEME_NAMES",
    "ALL_SCHEME_NAMES",
    "SimConfig",
    "make_scheduler",
    "policy_for",
    "run_grid",
    "run_one",
    "sweep_alpha",
    "sweep_gamma",
]
