"""Device lifecycle event streams for the churn runtime (paper §V-F).

The paper models a device's availability as ``P(ED) = exp(-lambda t)`` and
validates the exponential fit on a one-month campus mobility trace — but
the seed simulator only ever *sampled* one lifetime per device and let
tasks silently land on the departed.  This module turns the availability
model into an explicit event stream the engine can react to:

  * :func:`exponential_churn` — per-device exponential leave/rejoin cycles
    from the fleet's Table-IV rates (or any per-device override, e.g. the
    live lambda-MLE estimates of :class:`repro.ft.runtime.FleetMonitor`);
  * :func:`deterministic_churn` — an explicit ``(t, did, kind)`` script
    (tests, adversarial what-if schedules);
  * :func:`trace_churn` — replay of an availability trace: timestamped
    ``(t, did, alive)`` observations, exactly the shape
    :func:`repro.core.availability.fit_failure_rate` consumes — so one
    recorded trace can both fit the model and drive the simulator;
  * :func:`churn_from_monitor` — the ``sim``/``ft`` bridge: generate churn
    at the failure rates a :class:`FleetMonitor` estimated online, closing
    the loop between heartbeat-observed reality and simulated futures.

A :class:`ChurnSchedule` installed on a cluster becomes the single source
of truth for device lifetimes: each device's ``alive_until`` is set to its
first scheduled departure (``+inf`` if it never leaves), join events carry
the device's next departure so a rejoined device knows its new lifetime,
and the engine turns the events into DEVICE_DOWN / DEVICE_UP processing.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.cluster import ClusterState
from ..core.availability import sample_lifetime

__all__ = [
    "ChurnEvent",
    "ChurnSchedule",
    "exponential_churn",
    "deterministic_churn",
    "trace_churn",
    "churn_from_monitor",
]

LEAVE, JOIN = "leave", "join"


@dataclass(frozen=True)
class ChurnEvent:
    """One device lifecycle transition.

    ``until`` is only meaningful on ``join`` events: the device's next
    scheduled departure (``+inf`` if it stays), so the engine can re-arm
    ``alive_until`` — the ground truth the passive failure path and the
    in-flight ``ok`` precompute read — in O(1) at the event."""

    t: float
    did: int
    kind: str                       # "leave" | "join"
    until: float = float("inf")


@dataclass(frozen=True)
class ChurnSchedule:
    """A time-sorted stream of device leave/join events."""

    events: Tuple[ChurnEvent, ...]

    @property
    def n_events(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    def device_events(self, did: int) -> Tuple[ChurnEvent, ...]:
        return tuple(ev for ev in self.events if ev.did == did)

    def first_leave(self, did: int) -> float:
        for ev in self.events:
            if ev.did == did and ev.kind == LEAVE:
                return ev.t
        return float("inf")

    def install(self, cluster: ClusterState) -> "ChurnSchedule":
        """Make this schedule the single source of truth for the fleet's
        lifetimes: every device's ``alive_until`` becomes its first
        scheduled departure (``+inf`` when the schedule never removes it).
        Idempotent; returns self for chaining."""
        firsts: Dict[int, float] = {}
        for ev in self.events:
            if ev.kind == LEAVE and ev.did not in firsts:
                firsts[ev.did] = ev.t
        for d in cluster.devices:
            d.alive_until = firsts.get(d.did, float("inf"))
        cluster.refresh_topology()
        return self


def _finalize(events: List[ChurnEvent]) -> ChurnSchedule:
    """Sort by time and stamp each join event with the device's next
    departure (the rejoined lifetime the engine re-arms)."""
    events = sorted(events, key=lambda ev: (ev.t, ev.did))
    next_leave: Dict[int, List[float]] = {}
    for ev in events:
        if ev.kind == LEAVE:
            next_leave.setdefault(ev.did, []).append(ev.t)
    out: List[ChurnEvent] = []
    for ev in events:
        if ev.kind == JOIN:
            later = [t for t in next_leave.get(ev.did, []) if t > ev.t]
            until = min(later) if later else float("inf")
            out.append(ChurnEvent(ev.t, ev.did, JOIN, until))
        else:
            out.append(ev)
    return ChurnSchedule(events=tuple(out))


def exponential_churn(
    cluster: ClusterState,
    *,
    horizon: float,
    seed: int = 0,
    rejoin: bool = True,
    mean_downtime: float = 20.0,
    lams: Optional[Sequence[float]] = None,
    resample_first: bool = False,
) -> ChurnSchedule:
    """Exponential leave/rejoin cycles for every device, up to ``horizon``.

    Each device's first departure is its already-sampled ``alive_until``
    (so the schedule agrees with the fleet's ground truth and with every
    policy's Table-IV knowledge) unless ``resample_first`` — or the device
    was built immortal — in which case a fresh lifetime is drawn from its
    rate.  After a departure the device stays away ``Exp(mean_downtime)``
    seconds, then rejoins with a fresh exponential lifetime (memoryless, as
    the paper's model demands).  ``lams`` overrides the per-device rates —
    the hook :func:`churn_from_monitor` uses to feed online MLE estimates
    back into the generator.
    """
    rng = np.random.default_rng(seed)
    events: List[ChurnEvent] = []
    for d in cluster.devices:
        lam = float(lams[d.did]) if lams is not None else float(d.lam)
        if resample_first or not np.isfinite(d.alive_until):
            t_leave = d.join_time + sample_lifetime(lam, rng)
        else:
            t_leave = float(d.alive_until)
        while t_leave <= horizon:
            events.append(ChurnEvent(t_leave, d.did, LEAVE))
            if not rejoin:
                break
            t_join = t_leave + float(rng.exponential(mean_downtime))
            if t_join > horizon:
                break
            t_leave = t_join + sample_lifetime(lam, rng)
            events.append(ChurnEvent(t_join, d.did, JOIN))
    return _finalize(events)


def deterministic_churn(
    events: Iterable[Tuple[float, int, str]]
) -> ChurnSchedule:
    """An explicit script of ``(t, did, "leave"|"join")`` transitions."""
    out: List[ChurnEvent] = []
    for t, did, kind in events:
        if kind not in (LEAVE, JOIN):
            raise ValueError(f"unknown churn event kind {kind!r}")
        out.append(ChurnEvent(float(t), int(did), kind))
    return _finalize(out)


def trace_churn(
    observations: Iterable[Tuple[float, int, bool]]
) -> ChurnSchedule:
    """Replay an availability trace: ``(t, did, alive)`` observations (the
    campus-mobility-trace shape of §V-F).  A device emits a leave event
    when its observed state flips up -> down and a join event on the flip
    back; devices are assumed present before their first observation."""
    state: Dict[int, bool] = {}
    out: List[ChurnEvent] = []
    for t, did, alive in sorted(observations, key=lambda o: (o[0], o[1])):
        prev = state.get(did, True)
        alive = bool(alive)
        if prev and not alive:
            out.append(ChurnEvent(float(t), int(did), LEAVE))
        elif not prev and alive:
            out.append(ChurnEvent(float(t), int(did), JOIN))
        state[did] = alive
    return _finalize(out)


def churn_from_monitor(
    monitor,
    cluster: ClusterState,
    *,
    horizon: float,
    cls_key=None,
    **kwargs,
) -> ChurnSchedule:
    """Generate churn at the failure rates a
    :class:`repro.ft.runtime.FleetMonitor` estimated online.

    The monitor's per-class lambda MLE (deaths / alive-exposure — the same
    :func:`~repro.core.availability.fit_failure_rate` estimator the paper
    fits offline on the CrowdBind trace) replaces each device's nominal
    Table-IV rate, so ``sim`` and ``ft`` share one availability model.
    ``cls_key`` maps a sim :class:`~repro.core.cluster.Device` to the
    monitor's class label (default: ``str(device.cls)``).
    """
    key = cls_key if cls_key is not None else (lambda d: str(d.cls))
    lams = np.array([monitor.lam(key(d)) for d in cluster.devices])
    return exponential_churn(
        cluster, horizon=horizon, lams=lams, resample_first=True, **kwargs
    )
