"""Device lifecycle event streams for the churn runtime (paper §V-F).

The paper models a device's availability as ``P(ED) = exp(-lambda t)`` and
validates the exponential fit on a one-month campus mobility trace — but
the seed simulator only ever *sampled* one lifetime per device and let
tasks silently land on the departed.  This module turns the availability
model into an explicit event stream the engine can react to:

  * :func:`exponential_churn` — per-device exponential leave/rejoin cycles
    from the fleet's Table-IV rates (or any per-device override, e.g. the
    live lambda-MLE estimates of :class:`repro.ft.runtime.FleetMonitor`);
  * :func:`deterministic_churn` — an explicit ``(t, did, kind)`` script
    (tests, adversarial what-if schedules);
  * :func:`trace_churn` — replay of an availability trace: timestamped
    ``(t, did, alive)`` observations, exactly the shape
    :func:`repro.core.availability.fit_failure_rate` consumes — so one
    recorded trace can both fit the model and drive the simulator;
  * :func:`churn_from_monitor` — the ``sim``/``ft`` bridge: generate churn
    at the failure rates a :class:`FleetMonitor` estimated online, closing
    the loop between heartbeat-observed reality and simulated futures;
  * :func:`maintenance_windows` — scripted mass drains: whole device groups
    leave at a known instant and return together (the "end of a lecture
    empties the room" shape of mobility traces, arXiv:2110.07808);
  * :func:`correlated_churn` — Marshall–Olkin-style shared shocks: each
    group carries a Poisson shock process that departs every member at
    once, compounded with per-device individual churn and (optionally)
    scripted maintenance windows — the correlated mass-departure stress
    the per-device-independent generators cannot produce.

Determinism contract: every stochastic generator draws each device's
lifetimes from ONE stream keyed by ``(seed, device_id)`` (and each group's
shocks from a stream keyed by the group), so adding or removing a device
never reshuffles any other device's schedule — fleets are extensible
under common random numbers.

A :class:`ChurnSchedule` installed on a cluster becomes the single source
of truth for device lifetimes: each device's ``alive_until`` is set to its
first scheduled departure (``+inf`` if it never leaves), join events carry
the device's next departure so a rejoined device knows its new lifetime,
and the engine turns the events into DEVICE_DOWN / DEVICE_UP processing.
Schedules also carry their *forecastable* side — per-device known departure
times (scripted windows) plus residual stochastic rates — which ``install``
turns into a :class:`~repro.core.availability.SurvivalForecast` on the
cluster, making the churn schedule a first-class policy input (the
``churn_aware`` policy plans around it) instead of only an engine event
source.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.cluster import ClusterState
from ..core.availability import SurvivalForecast, sample_lifetime

__all__ = [
    "ChurnEvent",
    "ChurnSchedule",
    "exponential_churn",
    "deterministic_churn",
    "trace_churn",
    "churn_from_monitor",
    "maintenance_windows",
    "correlated_churn",
    "periodic_windows",
    "device_groups",
]

LEAVE, JOIN = "leave", "join"


@dataclass(frozen=True)
class ChurnEvent:
    """One device lifecycle transition.

    ``until`` is only meaningful on ``join`` events: the device's next
    scheduled departure (``+inf`` if it stays), so the engine can re-arm
    ``alive_until`` — the ground truth the passive failure path and the
    in-flight ``ok`` precompute read — in O(1) at the event."""

    t: float
    did: int
    kind: str                       # "leave" | "join"
    until: float = float("inf")


@dataclass(frozen=True)
class ChurnSchedule:
    """A time-sorted stream of device leave/join events.

    ``known_departures``/``forecast_lams`` carry the schedule's
    *forecastable* side (what an orchestrator could plausibly know in
    advance): per-device scripted departure times, and residual stochastic
    hazard rates for the unpredictable component.  Schedules built from raw
    events (``ChurnSchedule(events)``) carry neither — they install no
    forecast and policies keep pricing failures through ``F(T_i)`` alone.
    """

    events: Tuple[ChurnEvent, ...]
    # per-device KNOWN future departure times (sorted); None = none scripted
    known_departures: Optional[Dict[int, Tuple[float, ...]]] = None
    # per-device stochastic hazard rates of the unpredictable component
    forecast_lams: Optional[Tuple[float, ...]] = None

    @property
    def n_events(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    def device_events(self, did: int) -> Tuple[ChurnEvent, ...]:
        return tuple(ev for ev in self.events if ev.did == did)

    def first_leave(self, did: int) -> float:
        for ev in self.events:
            if ev.did == did and ev.kind == LEAVE:
                return ev.t
        return float("inf")

    # -- availability forecast (the schedule as a policy input) ---------------
    def forecaster(
        self, n_devices: int, *, horizon: float = 30.0, n_points: int = 16
    ) -> Optional[SurvivalForecast]:
        """Build the :class:`SurvivalForecast` this schedule supports, or
        None when the schedule carries no forecast metadata (hand-built
        event lists)."""
        if self.known_departures is None and self.forecast_lams is None:
            return None
        known = self.known_departures or {}
        deps = tuple(known.get(d, ()) for d in range(n_devices))
        lams = self.forecast_lams
        if lams is not None and len(lams) != n_devices:
            raise ValueError(
                f"forecast_lams covers {len(lams)} devices, asked for "
                f"{n_devices}"
            )
        return SurvivalForecast(
            departures=deps, lams=lams, horizon=horizon, n_points=n_points
        )

    def forecast(
        self,
        t: float,
        horizon: float = 30.0,
        *,
        n_points: int = 16,
        n_devices: Optional[int] = None,
    ) -> np.ndarray:
        """(D, K) survival-probability tensor at instant ``t``: entry
        ``[d, k]`` is P(device ``d`` stays up throughout
        ``[t, t + k/(K-1) * horizon]``).  Exact (0/1 cliffs) for the
        scripted component, ``exp(-lambda h)``-extrapolated for the
        stochastic one, all-ones when the schedule is not forecastable."""
        if n_devices is None:
            dids = [ev.did for ev in self.events]
            if self.known_departures:
                dids += list(self.known_departures)
            if self.forecast_lams is not None:
                dids.append(len(self.forecast_lams) - 1)
            n_devices = max(dids) + 1 if dids else 0
        fc = self.forecaster(n_devices, horizon=horizon, n_points=n_points)
        if fc is None:
            return np.ones((n_devices, n_points))
        return fc.sample(t)

    def install(self, cluster: ClusterState) -> "ChurnSchedule":
        """Make this schedule the single source of truth for the fleet's
        lifetimes: every device's ``alive_until`` becomes its first
        scheduled departure (``+inf`` when the schedule never removes it),
        and the schedule's forecastable side — if any — is installed as the
        cluster's :class:`SurvivalForecast` (the ``churn_aware`` policy's
        input).  Idempotent; returns self for chaining."""
        firsts: Dict[int, float] = {}
        for ev in self.events:
            if ev.kind == LEAVE and ev.did not in firsts:
                firsts[ev.did] = ev.t
        for d in cluster.devices:
            d.alive_until = firsts.get(d.did, float("inf"))
        fc = self.forecaster(cluster.n_devices)
        if fc is not None:
            cluster.install_forecast(fc)
        cluster.refresh_topology()
        return self


def _finalize(
    events: List[ChurnEvent],
    *,
    known: Optional[Dict[int, Tuple[float, ...]]] = None,
    lams: Optional[Sequence[float]] = None,
) -> ChurnSchedule:
    """Sort by time and stamp each join event with the device's next
    departure (the rejoined lifetime the engine re-arms)."""
    events = sorted(events, key=lambda ev: (ev.t, ev.did))
    next_leave: Dict[int, List[float]] = {}
    for ev in events:
        if ev.kind == LEAVE:
            next_leave.setdefault(ev.did, []).append(ev.t)
    out: List[ChurnEvent] = []
    for ev in events:
        if ev.kind == JOIN:
            later = [t for t in next_leave.get(ev.did, []) if t > ev.t]
            until = min(later) if later else float("inf")
            out.append(ChurnEvent(ev.t, ev.did, JOIN, until))
        else:
            out.append(ev)
    return ChurnSchedule(
        events=tuple(out),
        known_departures=(
            {d: tuple(sorted(ts)) for d, ts in known.items()}
            if known is not None else None
        ),
        forecast_lams=(
            tuple(float(l) for l in lams) if lams is not None else None
        ),
    )


# -- deterministic per-entity rng streams --------------------------------------
def _device_rng(seed: int, did: int) -> np.random.Generator:
    """ONE stream per (churn seed, device): every stochastic generator draws
    this device's lifetimes from here, so fleet membership changes cannot
    reshuffle anyone else's schedule."""
    return np.random.default_rng(
        np.random.SeedSequence(entropy=(int(seed), int(did)))
    )


def _group_rng(seed: int, gidx: int) -> np.random.Generator:
    """Per-group shock stream, namespaced away from the device streams."""
    return np.random.default_rng(
        np.random.SeedSequence(entropy=(int(seed), 0x53484B, int(gidx)))
    )


# -- down-interval plumbing ----------------------------------------------------
def _union_intervals(
    ivals: List[Tuple[float, float]]
) -> List[Tuple[float, float]]:
    """Merge overlapping/touching [t0, t1) down intervals."""
    out: List[List[float]] = []
    for t0, t1 in sorted(ivals):
        if out and t0 <= out[-1][1]:
            out[-1][1] = max(out[-1][1], t1)
        else:
            out.append([t0, t1])
    return [(a, b) for a, b in out]


def _events_from_down(
    did: int,
    ivals: List[Tuple[float, float]],
    horizon: Optional[float] = None,
) -> List[ChurnEvent]:
    """Turn a device's (possibly overlapping) down intervals into an
    alternating leave/join event list.  A join past ``horizon`` is dropped
    (the device simply stays away for the rest of the run)."""
    evs: List[ChurnEvent] = []
    for t0, t1 in _union_intervals(ivals):
        if horizon is not None and t0 > horizon:
            continue
        evs.append(ChurnEvent(float(t0), did, LEAVE))
        if np.isfinite(t1) and (horizon is None or t1 <= horizon):
            evs.append(ChurnEvent(float(t1), did, JOIN))
        else:
            break                       # down for the rest of the run
    return evs


def _individual_down_intervals(
    lam: float,
    first_leave: float,
    horizon: float,
    rejoin: bool,
    mean_downtime: float,
    rng: np.random.Generator,
) -> List[Tuple[float, float]]:
    """One device's exponential leave/rejoin cycle as down intervals."""
    out: List[Tuple[float, float]] = []
    t_leave = first_leave
    while t_leave <= horizon:
        if not rejoin:
            out.append((t_leave, float("inf")))
            break
        t_join = t_leave + float(rng.exponential(mean_downtime))
        if t_join > horizon:
            out.append((t_leave, float("inf")))
            break
        out.append((t_leave, t_join))
        t_leave = t_join + sample_lifetime(lam, rng)
    return out


def _ingest_windows(
    windows: Iterable[Tuple[float, Optional[float], Iterable[int]]],
    down: Dict[int, List[Tuple[float, float]]],
    known: Dict[int, List[float]],
) -> None:
    """Fold scripted ``(t0, t1, dids)`` drains into the per-device down
    intervals and the known-departure ledger (shared by
    :func:`maintenance_windows` and :func:`correlated_churn`).  Schedules
    are fleet-agnostic: any device id is accepted; validation against a
    concrete fleet happens at ``install``."""
    for t0, t1, dids in windows:
        t1 = float("inf") if t1 is None else float(t1)
        if t1 <= float(t0):
            raise ValueError(f"empty maintenance window [{t0}, {t1})")
        for did in dids:
            down.setdefault(int(did), []).append((float(t0), t1))
            known.setdefault(int(did), []).append(float(t0))


def device_groups(n_devices: int, n_groups: int) -> List[Tuple[int, ...]]:
    """Default correlated-churn grouping: device ``d`` belongs to group
    ``d % n_groups`` (on the standard fleets this groups by device class —
    one "room" per hardware class)."""
    return [
        tuple(d for d in range(n_devices) if d % n_groups == g)
        for g in range(n_groups)
    ]


def periodic_windows(
    groups: Sequence[Sequence[int]],
    *,
    period: float,
    duration: float,
    horizon: float,
    phase: float = 1.0,
) -> List[Tuple[float, float, Tuple[int, ...]]]:
    """Rotating scripted maintenance drains: window ``i`` starts at
    ``phase + i * period``, lasts ``duration`` seconds, and empties group
    ``i % len(groups)`` (the lecture-timetable shape)."""
    out: List[Tuple[float, float, Tuple[int, ...]]] = []
    i, t = 0, float(phase)
    while t <= horizon:
        out.append((t, t + float(duration), tuple(groups[i % len(groups)])))
        i += 1
        t += float(period)
    return out


def exponential_churn(
    cluster: ClusterState,
    *,
    horizon: float,
    seed: int = 0,
    rejoin: bool = True,
    mean_downtime: float = 20.0,
    lams: Optional[Sequence[float]] = None,
    resample_first: bool = False,
) -> ChurnSchedule:
    """Exponential leave/rejoin cycles for every device, up to ``horizon``.

    Each device's first departure is its already-sampled ``alive_until``
    (so the schedule agrees with the fleet's ground truth and with every
    policy's Table-IV knowledge) unless ``resample_first`` — or the device
    was built immortal — in which case a fresh lifetime is drawn from its
    rate.  After a departure the device stays away ``Exp(mean_downtime)``
    seconds, then rejoins with a fresh exponential lifetime (memoryless, as
    the paper's model demands).  ``lams`` overrides the per-device rates —
    the hook :func:`churn_from_monitor` uses to feed online MLE estimates
    back into the generator.

    Every device draws from its own ``(seed, did)``-keyed stream, so
    growing or shrinking the fleet leaves every other device's lifetimes
    untouched.  The resulting schedule is forecastable only stochastically:
    ``install`` attaches a rate-extrapolated :class:`SurvivalForecast`
    (``exp(-lambda h)``), never the sampled departure times themselves —
    memoryless departures are by definition not predictable.
    """
    events: List[ChurnEvent] = []
    rates: List[float] = []
    for d in cluster.devices:
        lam = float(lams[d.did]) if lams is not None else float(d.lam)
        rates.append(lam)
        rng = _device_rng(seed, d.did)
        if resample_first or not np.isfinite(d.alive_until):
            t_leave = d.join_time + sample_lifetime(lam, rng)
        else:
            t_leave = float(d.alive_until)
        ivals = _individual_down_intervals(
            lam, t_leave, horizon, rejoin, mean_downtime, rng
        )
        events.extend(_events_from_down(d.did, ivals, horizon))
    return _finalize(events, lams=rates)


def deterministic_churn(
    events: Iterable[Tuple[float, int, str]]
) -> ChurnSchedule:
    """An explicit script of ``(t, did, "leave"|"join")`` transitions.

    Scripted means *announced*: every departure time is carried in the
    schedule's ``known_departures``, so ``install`` attaches an exact
    availability forecast the ``churn_aware`` policy can plan around."""
    out: List[ChurnEvent] = []
    known: Dict[int, List[float]] = {}
    for t, did, kind in events:
        if kind not in (LEAVE, JOIN):
            raise ValueError(f"unknown churn event kind {kind!r}")
        out.append(ChurnEvent(float(t), int(did), kind))
        if kind == LEAVE:
            known.setdefault(int(did), []).append(float(t))
    return _finalize(
        out, known={d: tuple(ts) for d, ts in known.items()}
    )


def trace_churn(
    observations: Iterable[Tuple[float, int, bool]]
) -> ChurnSchedule:
    """Replay an availability trace: ``(t, did, alive)`` observations (the
    campus-mobility-trace shape of §V-F).  A device emits a leave event
    when its observed state flips up -> down and a join event on the flip
    back; devices are assumed present before their first observation.
    Replays are scripted futures, so — like :func:`deterministic_churn` —
    the departures are exported as an exact forecast."""
    state: Dict[int, bool] = {}
    out: List[ChurnEvent] = []
    known: Dict[int, List[float]] = {}
    for t, did, alive in sorted(observations, key=lambda o: (o[0], o[1])):
        prev = state.get(did, True)
        alive = bool(alive)
        if prev and not alive:
            out.append(ChurnEvent(float(t), int(did), LEAVE))
            known.setdefault(int(did), []).append(float(t))
        elif not prev and alive:
            out.append(ChurnEvent(float(t), int(did), JOIN))
        state[did] = alive
    return _finalize(out, known={d: tuple(ts) for d, ts in known.items()})


def maintenance_windows(
    windows: Iterable[Tuple[float, Optional[float], Iterable[int]]]
) -> ChurnSchedule:
    """Scripted mass drains: each window ``(t0, t1, dids)`` takes every
    listed device down at ``t0`` and returns the whole group at ``t1``
    (``None``/inf = they never come back).  Overlapping windows merge.

    The entire schedule is announced in advance, so ``install`` attaches an
    exact forecast: a task whose estimated span crosses a member's next
    window start has survival exactly 0 there — the cliff the
    ``churn_aware`` placement guard keys on."""
    down: Dict[int, List[Tuple[float, float]]] = {}
    known: Dict[int, List[float]] = {}
    _ingest_windows(windows, down, known)
    events: List[ChurnEvent] = []
    for did, ivals in down.items():
        events.extend(_events_from_down(did, ivals))
    return _finalize(
        events, known={d: tuple(ts) for d, ts in known.items()}
    )


def correlated_churn(
    cluster: ClusterState,
    *,
    horizon: float,
    seed: int = 0,
    groups: Optional[Sequence[Sequence[int]]] = None,
    n_groups: int = 8,
    shock_rate: float = 0.005,
    rejoin: bool = True,
    mean_downtime: float = 20.0,
    lams: Optional[Sequence[float]] = None,
    windows: Iterable[Tuple[float, Optional[float], Iterable[int]]] = (),
    resample_first: bool = False,
) -> ChurnSchedule:
    """Cluster-level correlated churn: Marshall–Olkin shared shocks plus
    scripted maintenance windows on top of per-device individual cycles.

    Three hazard sources compose (their down intervals union per device):

      * **individual** — each device's own exponential leave/rejoin cycle,
        drawn from its ``(seed, did)``-keyed stream exactly like
        :func:`exponential_churn` (the two generators share the contract:
        same seed -> same individual lifetimes);
      * **shared shocks** — each group carries a Poisson process with rate
        ``shock_rate``; when it fires, EVERY member departs at that instant
        and the whole group returns together after ``Exp(mean_downtime)``
        (the lecture ends, the room empties).  Groups default to
        :func:`device_groups` (device ``d`` -> group ``d % n_groups``);
      * **windows** — scripted ``(t0, t1, dids)`` drains (see
        :func:`maintenance_windows`), e.g. from :func:`periodic_windows`.

    Forecastability follows the sources: window departures are exported
    exactly (``known_departures``), while the individual and shock hazards
    are exported as rates — device ``d``'s residual forecast rate is
    ``lam_d + shock_rate`` (a shock departs it like any other failure, just
    correlated with its roommates)."""
    D = cluster.n_devices
    if groups is None:
        groups = device_groups(D, n_groups)
    down: Dict[int, List[Tuple[float, float]]] = {d.did: [] for d in cluster.devices}
    known: Dict[int, List[float]] = {}
    rates = np.array(
        [float(lams[d.did]) if lams is not None else float(d.lam)
         for d in cluster.devices]
    )

    # individual component: the exponential_churn contract, stream-for-stream
    for d in cluster.devices:
        rng = _device_rng(seed, d.did)
        if resample_first or not np.isfinite(d.alive_until):
            t_leave = d.join_time + sample_lifetime(float(rates[d.did]), rng)
        else:
            t_leave = float(d.alive_until)
        down[d.did].extend(_individual_down_intervals(
            float(rates[d.did]), t_leave, horizon, rejoin, mean_downtime, rng
        ))

    # shared shocks: one Poisson stream per group, mass departure + return
    shock_of = np.zeros(D)
    for g, members in enumerate(groups):
        members = [int(m) for m in members]
        if not members:
            continue
        shock_of[members] = shock_rate
        if shock_rate <= 0:
            continue
        rng = _group_rng(seed, g)
        t = float(rng.exponential(1.0 / shock_rate))
        while t <= horizon:
            dt = float(rng.exponential(mean_downtime))
            for did in members:
                down[did].append(
                    (t, t + dt if rejoin else float("inf"))
                )
            if not rejoin:
                break
            t = t + dt + float(rng.exponential(1.0 / shock_rate))

    # scripted windows: the forecast-exact component
    _ingest_windows(windows, down, known)

    events: List[ChurnEvent] = []
    for did, ivals in down.items():
        if ivals:
            events.extend(_events_from_down(did, ivals, horizon))
    return _finalize(
        events,
        known={d: tuple(ts) for d, ts in known.items()},
        lams=rates + shock_of,
    )


def churn_from_monitor(
    monitor,
    cluster: ClusterState,
    *,
    horizon: float,
    cls_key=None,
    **kwargs,
) -> ChurnSchedule:
    """Generate churn at the failure rates a
    :class:`repro.ft.runtime.FleetMonitor` estimated online.

    The monitor's per-class lambda MLE (deaths / alive-exposure — the same
    :func:`~repro.core.availability.fit_failure_rate` estimator the paper
    fits offline on the CrowdBind trace) replaces each device's nominal
    Table-IV rate, so ``sim`` and ``ft`` share one availability model —
    and the resulting schedule's forecast extrapolates those same MLE
    rates.  ``cls_key`` maps a sim :class:`~repro.core.cluster.Device` to
    the monitor's class label (default: ``str(device.cls)``).
    """
    key = cls_key if cls_key is not None else (lambda d: str(d.cls))
    lams = np.array([monitor.lam(key(d)) for d in cluster.devices])
    return exponential_churn(
        cluster, horizon=horizon, lams=lams, resample_first=True, **kwargs
    )
