"""Experiment runner: reproduces the paper's evaluation grids (§V-G..J).

Protocol (paper §V-G): ``n_cycles`` cycles of ``cycle_len`` seconds;
``instances_per_cycle`` application instances arrive uniformly inside the
first ``arrival_window`` seconds of each cycle; the application mix is
uniform over the four test applications; the fleet is ``n_devices`` devices
uniform over the 8 Table-III classes.

Fairness: every scheme sees the *same* environment draw — identical device
lifetimes, arrival times and application instances (common random numbers).

Every scheme is built through the policy registry
(``make_policy(name, **kwargs)``) and driven online through the unified
:class:`repro.api.Orchestrator` façade — there is no per-scheme
construction code here.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.dag import AppDAG
from ..core.policy import Policy, available_policies, make_policy
from .apps import APP_BUILDERS
from .engine import Engine, SimResult
from .profiles import EdgeProfile, make_cluster, make_profile

__all__ = [
    "SimConfig",
    "policy_for",
    "make_churn",
    "run_one",
    "run_grid",
    "sweep_alpha",
    "sweep_gamma",
    "SCHEME_NAMES",
    "ALL_SCHEME_NAMES",
]

SCHEME_NAMES = ("ibdash", "lats", "lavea", "petrel", "round_robin", "random")
# The paper's six schemes plus the multi-tier escalation policy (which only
# differs from greedy-min-latency on fleets that declare tiers) and the
# forecast-aware IBDASH variant (which only differs from ibdash on clusters
# with an installed availability forecast).
ALL_SCHEME_NAMES = SCHEME_NAMES + ("tier_escalation", "churn_aware")


@dataclass
class SimConfig:
    scenario: str = "mix"
    n_devices: int = 100
    n_cycles: int = 20
    cycle_len: float = 15.0
    arrival_window: float = 1.5
    instances_per_cycle: int = 1000
    seed: int = 0
    noise_sigma: float = 0.10
    alpha: float = 0.5
    beta: float = 0.1
    gamma: int = 3
    # tier_escalation: escalate device -> edge -> cloud once the best
    # same-or-lower-tier candidate's Eq. (2) latency exceeds this budget.
    latency_budget: float = float("inf")
    # Plan each cycle's burst in one fused `orchestrate_batch` wave (all
    # plans share the cycle-start fleet snapshot) instead of per arrival.
    fused_burst: bool = False
    # -- churn runtime (repro.sim.churn + repro.core.recovery) -----------------
    # Recovery strategy when a task loses its last replica: "fail_fast"
    # (Eq. 4, bit-identical to the seed engine), "failover", or "replan".
    recovery: str = "fail_fast"
    # None = churn auto-enables for the churn scenarios only; True/False forces.
    churn: Optional[bool] = None
    churn_seed: Optional[int] = None    # None = seed + 101
    rejoin: bool = True                 # departed devices rejoin after downtime
    mean_downtime: float = 20.0         # Exp() mean seconds away per departure
    detection_delay: float = 0.25       # missed-heartbeat detection lag
    max_retries: int = 2                # failover/replan attempts per task
    # Partial-result salvage attempts per instance (0 = off): a lost
    # instance with completed stages is re-planned via orchestrate(pinned=)
    # instead of discarded.
    salvage: int = 0
    # -- correlated churn (scenario "correlated_churn") ------------------------
    churn_groups: int = 8               # shared-shock groups (did % groups)
    shock_rate: float = 0.005           # per-group mass-departure rate (1/s)
    maintenance_period: float = 7.5     # one scripted drain per period...
    maintenance_duration: float = 5.0   # ...taking a group down this long
    maintenance_phase: float = 1.0      # first window start offset
    # -- streaming service (scenario "stream"; repro.stream) -------------------
    stream_rate: float = 120.0          # offered load, instances/sec
    stream_process: str = "poisson"     # "poisson" | "diurnal"
    stream_peak_rate: Optional[float] = None  # diurnal peak (None = 2x rate)
    stream_period: float = 60.0         # diurnal period, seconds
    stream_queue_cap: Optional[int] = 512
    stream_admission: bool = True       # False = no-admission baseline
    stream_tick: float = 0.25           # service-loop dispatch tick
    stream_wave: Optional[int] = None   # max instances per dispatch wave
    slo_critical: float = 6.0           # latency_critical E2E budget (s)
    slo_best_effort: float = 30.0       # best_effort E2E budget (s)
    stream_metrics_interval: float = 1.0
    # -- observability (repro.obs) ---------------------------------------------
    # True: attach a Tracer to the engine; the returned SimResult carries
    # it as ``res.trace`` (spans for attribution / Chrome export).
    trace: bool = False

    @property
    def churn_enabled(self) -> bool:
        if self.churn is not None:
            return self.churn
        return self.scenario in ("churn", "correlated_churn")

    @property
    def horizon(self) -> float:
        return self.n_cycles * self.cycle_len


def policy_for(name: str, profile: EdgeProfile, cfg: SimConfig) -> Policy:
    """Uniform registry construction: one kwarg bundle serves every scheme."""
    return make_policy(
        name,
        alpha=cfg.alpha,
        beta=cfg.beta,
        gamma=cfg.gamma,
        seed=cfg.seed,
        lats_model=profile.lats_model,
        latency_budget=cfg.latency_budget,
    )


def _make_workload(cfg: SimConfig) -> Tuple[List[AppDAG], List[float]]:
    """Deterministic (apps, arrival times) shared by every scheme."""
    rng = np.random.default_rng(cfg.seed + 1)
    builders = list(APP_BUILDERS.values())
    apps: List[AppDAG] = []
    times: List[float] = []
    uid = 0
    for c in range(cfg.n_cycles):
        t0 = c * cfg.cycle_len
        arr = np.sort(rng.uniform(0.0, cfg.arrival_window, cfg.instances_per_cycle))
        for t in arr:
            base = builders[int(rng.integers(len(builders)))]()
            apps.append(base.relabel(f"#{uid}"))
            times.append(float(t0 + t))
            uid += 1
    return apps, times


def make_churn(cfg: SimConfig, cluster) -> Optional["ChurnSchedule"]:
    """Build the scenario's churn schedule over an already-built cluster
    (shared by run_one, the churn benchmark and the demo): exponential
    leave/rejoin cycles by default, the correlated generator — per-group
    shared shocks plus rotating scripted maintenance windows — for
    scenario "correlated_churn".  Returns None when churn is disabled."""
    if not cfg.churn_enabled:
        return None
    # lazy: keeps the import graph flat
    from .churn import (
        correlated_churn,
        device_groups,
        exponential_churn,
        periodic_windows,
    )

    seed = cfg.seed + 101 if cfg.churn_seed is None else cfg.churn_seed
    horizon = cfg.horizon + 25.0
    if cfg.scenario == "correlated_churn":
        groups = device_groups(cluster.n_devices, cfg.churn_groups)
        windows = periodic_windows(
            groups,
            period=cfg.maintenance_period,
            duration=cfg.maintenance_duration,
            horizon=horizon,
            phase=cfg.maintenance_phase,
        )
        return correlated_churn(
            cluster, horizon=horizon, seed=seed, groups=groups,
            shock_rate=cfg.shock_rate, rejoin=cfg.rejoin,
            mean_downtime=cfg.mean_downtime, windows=windows,
        )
    return exponential_churn(
        cluster, horizon=horizon, seed=seed, rejoin=cfg.rejoin,
        mean_downtime=cfg.mean_downtime,
    )


def _run_stream(cfg: SimConfig, scheme: str, profile: EdgeProfile) -> SimResult:
    """Scenario ``"stream"``: open-loop arrivals through the always-on
    service (:mod:`repro.stream`) instead of the closed-loop cycle burst.
    The returned :class:`SimResult` carries the full
    :class:`~repro.stream.service.StreamResult` as ``res.stream``."""
    from ..api import Orchestrator
    from ..stream import (
        AdmissionConfig,
        StreamingOrchestrator,
        default_streams,
        diurnal_arrivals,
        poisson_arrivals,
    )

    # Generous horizon: the no-admission baseline drains its backlog long
    # after the last arrival.
    cluster = make_cluster(
        profile, scenario="stream", n_devices=cfg.n_devices, seed=cfg.seed,
        horizon=cfg.horizon * 3.0 + 60.0,
    )
    churn = make_churn(cfg, cluster)
    orch = Orchestrator(
        cluster, policy_for(scheme, profile, cfg),
        seed=cfg.seed, noise_sigma=cfg.noise_sigma,
        churn=churn, recovery=cfg.recovery, salvage=cfg.salvage,
        detection_delay=cfg.detection_delay, max_retries=cfg.max_retries,
        trace=cfg.trace,
    )
    streams = default_streams(
        slo_critical=cfg.slo_critical, slo_best_effort=cfg.slo_best_effort
    )
    if cfg.stream_process == "diurnal":
        peak = cfg.stream_peak_rate or 2.0 * cfg.stream_rate
        arrivals = diurnal_arrivals(
            streams, cfg.stream_rate, peak, cfg.horizon,
            period=cfg.stream_period, seed=cfg.seed + 7,
        )
    elif cfg.stream_process == "poisson":
        arrivals = poisson_arrivals(
            streams, cfg.stream_rate, cfg.horizon, seed=cfg.seed + 7,
        )
    else:
        raise ValueError(f"unknown stream_process {cfg.stream_process!r}")
    admission = (
        AdmissionConfig(queue_cap=cfg.stream_queue_cap)
        if cfg.stream_admission else None
    )
    service = StreamingOrchestrator(
        orch, admission=admission, tick=cfg.stream_tick,
        wave_cap=cfg.stream_wave,
        metrics_interval=cfg.stream_metrics_interval,
    )
    stream_res = service.run(arrivals)
    res = stream_res.result
    res.stream = stream_res            # SimResult is a plain dataclass
    if cfg.trace:
        res.trace = orch.trace
    return res


def run_one(
    scheme: str,
    cfg: SimConfig,
    profile: Optional[EdgeProfile] = None,
) -> SimResult:
    from ..api import Orchestrator  # lazy: api sits above sim in the layering

    profile = profile or make_profile(seed=cfg.seed)
    if cfg.scenario == "stream":
        return _run_stream(cfg, scheme, profile)
    cluster = make_cluster(
        profile, scenario=cfg.scenario, n_devices=cfg.n_devices, seed=cfg.seed,
        horizon=cfg.horizon + 30.0,
    )
    churn = make_churn(cfg, cluster)
    orch = Orchestrator(
        cluster, policy_for(scheme, profile, cfg),
        seed=cfg.seed, noise_sigma=cfg.noise_sigma,
        churn=churn, recovery=cfg.recovery, salvage=cfg.salvage,
        detection_delay=cfg.detection_delay, max_retries=cfg.max_retries,
        trace=cfg.trace,
    )
    apps, times = _make_workload(cfg)
    if cfg.fused_burst:
        # One fused wave per cycle: advance the clock to each cycle start,
        # then plan that cycle's burst against the fleet state at that
        # instant (running tasks from earlier cycles included).
        per = cfg.instances_per_cycle
        for c in range(cfg.n_cycles):
            orch.step(until=c * cfg.cycle_len)
            orch.submit_batch(
                apps[c * per:(c + 1) * per],
                times[c * per:(c + 1) * per],
                fused=True,
            )
    else:
        orch.submit_batch(apps, times)
    orch.step(until=cfg.horizon + 25.0)
    res = orch.result(scenario=cfg.scenario, horizon=cfg.horizon)
    if cfg.trace:
        res.trace = orch.trace
    return res


def run_grid(
    schemes: Sequence[str] = SCHEME_NAMES,
    scenarios: Sequence[str] = ("ced", "ped", "mix"),
    cfg: Optional[SimConfig] = None,
) -> Dict[Tuple[str, str], SimResult]:
    """The full Fig. 8 / Fig. 9 grid: scheme x scenario."""
    cfg = cfg or SimConfig()
    profile = make_profile(seed=cfg.seed)
    out: Dict[Tuple[str, str], SimResult] = {}
    for scen in scenarios:
        for scheme in schemes:
            out[(scheme, scen)] = run_one(
                scheme, replace(cfg, scenario=scen), profile
            )
    return out


def sweep_alpha(
    alphas: Sequence[float],
    cfg: Optional[SimConfig] = None,
) -> List[Tuple[float, float, float]]:
    """Fig. 12a: sweep the joint-optimisation weight.  Returns
    (alpha, avg service time, avg P_f) triples."""
    cfg = cfg or SimConfig(scenario="mix")
    profile = make_profile(seed=cfg.seed)
    rows = []
    for a in alphas:
        res = run_one("ibdash", replace(cfg, alpha=float(a)), profile)
        rows.append((float(a), res.avg_service_time, res.prob_failure))
    return rows


def sweep_gamma(
    gammas: Sequence[int],
    cfg: Optional[SimConfig] = None,
) -> List[Tuple[int, float, float, float]]:
    """Fig. 12b: sweep the replication-degree cap.  Returns
    (gamma, avg service time, avg P_f, avg #replicas) tuples."""
    cfg = cfg or SimConfig(scenario="ped")
    profile = make_profile(seed=cfg.seed)
    rows = []
    for g in gammas:
        res = run_one("ibdash", replace(cfg, gamma=int(g)), profile)
        nrep = float(np.mean([r.n_replicas for r in res.instances]))
        rows.append((int(g), res.avg_service_time, res.prob_failure, nrep))
    return rows
