"""Device & task profiles for the edge simulator (paper §V-B, Table III/IV).

The paper profiles every (task type × task type) interference pair on eight
real platforms (a MacBook Pro + seven EC2 instance types) and feeds the
measured (m, c) coefficients into its simulator.  We regenerate statistically
similar profiles from the published hardware specs:

  * base latency  c[p, i] = work_i / (freq_p * amdahl(cores_p, f_i))
  * slope         m[p, i, j] = c[p, i] * contention[i, j] * (4 / cores_p)^0.35

Relative slopes (slope/base ~ 0.2-0.35 for cpu-cpu pairs) are calibrated
against the paper's Fig. 4, where five co-located tasks roughly double the
service time on the MacBook.  Many-core high-frequency devices
(c5.4xlarge) still have the smallest bases *and* mildly smaller relative
slopes — the structure that makes LaTS concentrate load on the fastest
class in the paper's Fig. 10 while IBDASH spreads out.

All coefficients are deterministic given the seed.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.availability import LAMBDA_CED, LAMBDA_MIX, LAMBDA_PED, sample_lifetime
from ..core.policy import LaTSModel
from ..core.cluster import (
    TIER_CLOUD,
    TIER_DEVICE,
    TIER_EDGE_SERVER,
    ClusterState,
    Device,
)
from ..core.interference import InterferenceModel

__all__ = [
    "DeviceClass",
    "DEVICE_CLASSES",
    "TaskType",
    "TASK_TYPES",
    "EdgeProfile",
    "make_profile",
    "make_cluster",
    "make_multi_tier_cluster",
    "TierSpec",
    "MULTI_TIER_SPECS",
    "DEFAULT_BACKHAUL",
    "SCENARIOS",
    "CHURN_LAMBDA_SCALE",
    "LAMBDA_CHURN",
]

MB = 1e6
GB = 1e9


@dataclass(frozen=True)
class DeviceClass:
    """Table III row."""

    name: str
    cpus: int
    mem_gb: float
    freq_ghz: float
    bandwidth: float        # bytes/s network link (not in Table III; see §V-B "B")


# Table III of the paper.  Bandwidths: EC2 instances share a ~1 Gbps edge
# link; the MacBook sits on campus Wi-Fi.
DEVICE_CLASSES: Tuple[DeviceClass, ...] = (
    DeviceClass("macbook-pro-2017", 2, 8, 3.1, 50 * MB),
    DeviceClass("t2.xlarge", 4, 16, 2.3, 125 * MB),
    DeviceClass("t2.2xlarge", 8, 32, 2.3, 125 * MB),
    DeviceClass("t3.xlarge", 4, 16, 2.5, 125 * MB),
    DeviceClass("t3a.xlarge", 4, 16, 2.2, 125 * MB),
    DeviceClass("c5.2xlarge", 8, 16, 3.4, 125 * MB),
    DeviceClass("c5.4xlarge", 16, 32, 3.4, 125 * MB),
    DeviceClass("t3.2xlarge", 8, 32, 2.5, 125 * MB),
)


@dataclass(frozen=True)
class TaskType:
    """One entry of the global task-type table ``T`` (shared by all 4 apps).

    work           abstract compute units (calibrated so bases are ~0.05-0.6 s)
    parallel_frac  Amdahl parallel fraction (io-ish tasks parallelise poorly)
    cpu_frac       incremental CPU usage of one instance on a 4-core device
    kind           'cpu' | 'io'  (drives the contention matrix)
    """

    name: str
    work: float
    parallel_frac: float
    cpu_frac: float
    kind: str


TASK_TYPES: Tuple[TaskType, ...] = (
    TaskType("read_input", 0.25, 0.20, 0.15, "io"),      # 0  LightGBM
    TaskType("pca", 0.90, 0.75, 0.55, "cpu"),            # 1
    TaskType("train_tree", 1.40, 0.85, 0.70, "cpu"),     # 2
    TaskType("combine_test", 0.60, 0.60, 0.40, "cpu"),   # 3
    TaskType("map", 0.50, 0.55, 0.35, "io"),             # 4  MapReduce
    TaskType("reduce", 0.80, 0.70, 0.50, "cpu"),         # 5
    TaskType("split_video", 0.35, 0.30, 0.25, "io"),     # 6  Video analytics
    TaskType("extract_frame", 0.70, 0.65, 0.45, "cpu"),  # 7
    TaskType("classify", 1.10, 0.80, 0.65, "cpu"),       # 8
    TaskType("mat_inv", 1.30, 0.80, 0.70, "cpu"),        # 9  Matrix computation
    TaskType("mat_mul", 1.00, 0.90, 0.75, "cpu"),        # 10
    TaskType("mat_vec", 0.45, 0.60, 0.35, "cpu"),        # 11
)

N_TYPES = len(TASK_TYPES)

# The churn scenario's per-class failure rates: the PED (personal edge
# device) rates of Table IV scaled so that departures — and, with the churn
# runtime's rejoin cycles, re-admissions — actually happen inside the
# evaluation window (mean lifetimes drop from hours to ~1.5-10 minutes,
# the "campus corridor at class change" regime of the §V-F mobility trace).
CHURN_LAMBDA_SCALE = 12.0
LAMBDA_CHURN = LAMBDA_PED * CHURN_LAMBDA_SCALE

# Scenario name -> per-class failure rates (paper Table IV).  The extra
# "multi_tier" scenario (device -> edge server -> cloud fleet with the
# tier-aware link matrix; see make_multi_tier_cluster) is dispatched by
# make_cluster directly and has per-TIER rates in MULTI_TIER_SPECS.
# "churn" pairs the scaled-PED fleet with the churn runtime: the runner
# generates a leave/rejoin event stream over it (repro.sim.churn) and the
# engine reacts through the configured recovery strategy.
# "correlated_churn" keeps the plain PED background rates but drives the
# fleet with the CORRELATED generator (repro.sim.churn.correlated_churn):
# per-group Marshall-Olkin shared shocks plus rotating scripted maintenance
# windows — the mass-departure regime where the forecast-aware planner
# (make_policy("churn_aware")) earns its keep.
SCENARIOS: Dict[str, np.ndarray] = {
    "mix": LAMBDA_MIX,
    "ced": LAMBDA_CED,
    "ped": LAMBDA_PED,
    "churn": LAMBDA_CHURN,
    "correlated_churn": LAMBDA_PED,
    # The always-on streaming service runs over the standard mixed fleet;
    # what changes is the workload (open-loop arrivals through admission),
    # handled in repro.sim.runner / repro.stream.
    "stream": LAMBDA_MIX,
}


# -- multi-tier fleets (arXiv:2409.10839's device -> edge -> cloud shape) ------
@dataclass(frozen=True)
class TierSpec:
    """One fleet tier: its directional link rates, failure rate, and the
    Table-III compute classes its members cycle over."""

    tier: int
    classes: Tuple[int, ...]
    up_bw: float
    down_bw: float
    lam: float


# End devices are the flaky majority with phone-like asymmetric links (an
# uplink ~5x slower than the downlink — exactly the asymmetry the scalar
# receiver-only bandwidth model could not express); edge servers sit on the
# local backbone; the small cloud tier is fast but behind the WAN.
MULTI_TIER_SPECS: Tuple[TierSpec, ...] = (
    TierSpec(TIER_DEVICE, (0, 1, 3, 4), up_bw=8 * MB, down_bw=40 * MB,
             lam=9e-4),
    TierSpec(TIER_EDGE_SERVER, (2, 5, 7), up_bw=600 * MB, down_bw=600 * MB,
             lam=3e-5),
    TierSpec(TIER_CLOUD, (6,), up_bw=2500 * MB, down_bw=2500 * MB, lam=1e-7),
)

# (tier, tier) backhaul rates in bytes/s: device peers relay through the
# access point, device <-> cloud crosses the WAN, edge servers share the
# metro backbone.
DEFAULT_BACKHAUL = np.array([
    [25 * MB, 500 * MB, 40 * MB],
    [500 * MB, 1250 * MB, 150 * MB],
    [40 * MB, 150 * MB, 2500 * MB],
])


def make_multi_tier_cluster(
    profile: EdgeProfile,
    n_devices: int = 100,
    seed: int = 0,
    horizon: float = 330.0,
    dt: float = 0.05,
    edge_frac: float = 0.15,
    cloud_frac: float = 0.05,
    backhaul: np.ndarray = DEFAULT_BACKHAUL,
) -> ClusterState:
    """Build a 3-tier fleet of ``n_devices`` nodes: a large, flaky end-device
    tier, ~``edge_frac`` edge servers, and ~``cloud_frac`` cloud nodes,
    wired by per-device up/down rates plus the inter-tier ``backhaul``
    matrix (bottleneck rule ``min(up[s], down[d], backhaul[ts, td])``).
    Model artifacts are hosted on the first edge server, so uploads are
    charged over the device <-> server link."""
    if n_devices < 3:
        raise ValueError("a multi-tier fleet needs >= 3 devices (one per tier)")
    rng = np.random.default_rng(seed)
    n_cloud = max(1, int(round(n_devices * cloud_frac)))
    n_edge = max(1, int(round(n_devices * edge_frac)))
    n_end = n_devices - n_edge - n_cloud
    devices: List[Device] = []
    did = 0
    for spec, count in zip(MULTI_TIER_SPECS, (n_end, n_edge, n_cloud)):
        for k in range(count):
            cls = spec.classes[k % len(spec.classes)]
            devices.append(Device(
                did=did,
                cls=cls,
                mem_total=DEVICE_CLASSES[cls].mem_gb * GB,
                lam=spec.lam,
                tier=spec.tier,
                up_bw=spec.up_bw,
                down_bw=spec.down_bw,
                join_time=0.0,
                alive_until=sample_lifetime(spec.lam, rng),
            ))
            did += 1
    return ClusterState(
        devices=devices,
        model=profile.interference,
        horizon=horizon,
        dt=dt,
        backhaul=np.asarray(backhaul, dtype=np.float64),
        model_source=n_end,            # the first edge server hosts artifacts
    )


def _amdahl(cores: int, frac: float) -> float:
    return 1.0 / ((1.0 - frac) + frac / cores)


@dataclass
class EdgeProfile:
    """Everything the simulator needs about hardware + tasks."""

    interference: InterferenceModel
    lats_model: LaTSModel
    cpu_usage: np.ndarray            # (P, N)
    classes: Tuple[DeviceClass, ...] = DEVICE_CLASSES
    task_types: Tuple[TaskType, ...] = TASK_TYPES


def make_profile(seed: int = 0, calib: float = 0.55) -> EdgeProfile:
    """Generate the (m, c) interference tables + the LaTS latency-CPU model."""
    rng = np.random.default_rng(seed)
    P, N = len(DEVICE_CLASSES), N_TYPES

    base = np.zeros((P, N))
    cpu_usage = np.zeros((P, N))
    for p, dc in enumerate(DEVICE_CLASSES):
        for i, tt in enumerate(TASK_TYPES):
            # Tempered Amdahl: EC2 vCPUs are hyperthreads on burstable
            # instances, so the many-core advantage is milder than the raw
            # core count suggests (calibrated against the ~1.3-2x spread in
            # the paper's Fig. 8 service times across schemes/devices).
            speedup = dc.freq_ghz * _amdahl(dc.cpus, tt.parallel_frac) ** 0.55
            base[p, i] = calib * tt.work / speedup
            # cpu_frac is referenced to a 4-core device.
            cpu_usage[p, i] = min(tt.cpu_frac * 4.0 / dc.cpus, 1.0)

    # Pairwise contention: cpu-cpu pairs contend hard, io-involving pairs
    # less; the +-25% jitter reproduces the per-pair heterogeneity of Fig. 2.
    contention = np.zeros((N, N))
    for i, ti in enumerate(TASK_TYPES):
        for j, tj in enumerate(TASK_TYPES):
            if ti.kind == "cpu" and tj.kind == "cpu":
                c0 = 0.28
            elif ti.kind == "io" and tj.kind == "io":
                c0 = 0.16
            else:
                c0 = 0.10
            contention[i, j] = c0 * rng.uniform(0.75, 1.25)

    slope = np.zeros((P, N, N))
    for p, dc in enumerate(DEVICE_CLASSES):
        slope[p] = base[p][:, None] * contention * (4.0 / dc.cpus) ** 0.35

    interference = InterferenceModel(base=base, slope=slope)

    # Fit LaTS' log-linear latency-vs-usage model on profiling data generated
    # from the ground-truth interference model (paper Fig. 5 does this from
    # measurements): for each class, regress log(latency) on CPU usage.
    b = np.zeros(P)
    for p in range(P):
        xs, ys = [], []
        for _ in range(400):
            counts = rng.poisson(rng.uniform(0.3, 3.0), size=N).astype(np.float64)
            usage = min(float((cpu_usage[p] * counts).sum()), 4.0)
            i = int(rng.integers(N))
            lat = interference.estimate(p, i, counts)
            xs.append(usage)
            ys.append(np.log(lat / base[p, i]))
        A = np.stack([np.asarray(xs), np.ones(len(xs))], axis=1)
        (bp, _), *_ = np.linalg.lstsq(A, np.asarray(ys), rcond=None)
        b[p] = max(bp, 0.0)

    lats = LaTSModel(base=base.copy(), b=b, cpu_usage=cpu_usage.copy())
    return EdgeProfile(interference=interference, lats_model=lats, cpu_usage=cpu_usage)


def make_cluster(
    profile: EdgeProfile,
    scenario: str = "mix",
    n_devices: int = 100,
    seed: int = 0,
    horizon: float = 330.0,
    dt: float = 0.05,
) -> ClusterState:
    """Build the fleet: ``n_devices`` uniformly over the 8 classes (paper
    §V-G), ground-truth lifetimes drawn from the scenario's Table-IV rates.
    ``scenario="multi_tier"`` dispatches to :func:`make_multi_tier_cluster`
    (device -> edge server -> cloud with the tier-aware link matrix)."""
    if scenario == "multi_tier":
        return make_multi_tier_cluster(
            profile, n_devices=n_devices, seed=seed, horizon=horizon, dt=dt
        )
    lams = SCENARIOS[scenario]
    rng = np.random.default_rng(seed)
    devices: List[Device] = []
    for did in range(n_devices):
        cls = did % len(DEVICE_CLASSES)
        dc = DEVICE_CLASSES[cls]
        lam = float(lams[cls])
        devices.append(
            Device(
                did=did,
                cls=cls,
                mem_total=dc.mem_gb * GB,
                lam=lam,
                up_bw=dc.bandwidth,
                down_bw=dc.bandwidth,
                join_time=0.0,
                alive_until=sample_lifetime(lam, rng),
            )
        )
    return ClusterState(
        devices=devices, model=profile.interference, horizon=horizon, dt=dt
    )
