"""The four DAG applications used in the paper's evaluation (§V-C, Fig. 6).

  (1) LightGBM          read -> PCA -> {train_tree x K} -> combine/test
  (2) MapReduce sort    {map x M} -> {reduce x R}
  (3) Video analytics   split -> {extract_frame x C} -> classify
  (4) Matrix compute    {mat_mul, mat_inv} -> mat_mul -> mat_vec

Task-type ids index :data:`repro.sim.profiles.TASK_TYPES`.  Data sizes are
chosen so cross-device transfers cost 0.05-0.5 s at ~100 MB/s links and
model uploads are expensive enough that artifact-cache awareness matters —
matching the regimes in the paper's Figs. 8-11.
"""
from __future__ import annotations

from typing import Callable, Dict, List

from ..core.dag import AppDAG, TaskSpec

MB = 1e6

__all__ = ["lightgbm_app", "mapreduce_app", "video_app", "matrix_app", "APP_BUILDERS", "all_apps"]


def lightgbm_app(n_trees: int = 6) -> AppDAG:
    tasks: List[TaskSpec] = [
        TaskSpec("read", ttype=0, out_bytes=40 * MB, mem_bytes=300 * MB),
        TaskSpec("pca", ttype=1, deps=("read",), out_bytes=12 * MB, mem_bytes=500 * MB),
    ]
    for k in range(n_trees):
        tasks.append(
            TaskSpec(
                f"train{k}", ttype=2, deps=("pca",), out_bytes=4 * MB,
                model_id="lgbm-lib", model_bytes=60 * MB, mem_bytes=800 * MB,
            )
        )
    tasks.append(
        TaskSpec(
            "combine", ttype=3, deps=tuple(f"train{k}" for k in range(n_trees)),
            out_bytes=1 * MB, mem_bytes=400 * MB,
        )
    )
    return AppDAG.from_tasks("lightgbm", tasks)


def mapreduce_app(n_map: int = 4, n_reduce: int = 2) -> AppDAG:
    tasks: List[TaskSpec] = [
        TaskSpec(f"map{m}", ttype=4, out_bytes=25 * MB, mem_bytes=400 * MB)
        for m in range(n_map)
    ]
    maps = tuple(f"map{m}" for m in range(n_map))
    for r in range(n_reduce):
        tasks.append(
            TaskSpec(f"reduce{r}", ttype=5, deps=maps, out_bytes=10 * MB,
                     mem_bytes=600 * MB)
        )
    return AppDAG.from_tasks("mapreduce", tasks)


def video_app(n_chunks: int = 4) -> AppDAG:
    tasks: List[TaskSpec] = [
        TaskSpec("split", ttype=6, out_bytes=30 * MB, mem_bytes=350 * MB)
    ]
    for c in range(n_chunks):
        tasks.append(
            TaskSpec(f"extract{c}", ttype=7, deps=("split",), out_bytes=3 * MB,
                     mem_bytes=450 * MB)
        )
    tasks.append(
        TaskSpec(
            "classify", ttype=8, deps=tuple(f"extract{c}" for c in range(n_chunks)),
            out_bytes=0.2 * MB, model_id="resnet", model_bytes=160 * MB,
            mem_bytes=900 * MB,
        )
    )
    return AppDAG.from_tasks("video", tasks)


def matrix_app() -> AppDAG:
    tasks = [
        TaskSpec("mm0", ttype=10, out_bytes=16 * MB, mem_bytes=500 * MB),
        TaskSpec("inv0", ttype=9, out_bytes=16 * MB, mem_bytes=500 * MB),
        TaskSpec("mm1", ttype=10, deps=("mm0", "inv0"), out_bytes=16 * MB,
                 mem_bytes=500 * MB),
        TaskSpec("mv0", ttype=11, deps=("mm1",), out_bytes=0.1 * MB,
                 mem_bytes=250 * MB),
    ]
    return AppDAG.from_tasks("matrix", tasks)


APP_BUILDERS: Dict[str, Callable[[], AppDAG]] = {
    "lightgbm": lightgbm_app,
    "mapreduce": mapreduce_app,
    "video": video_app,
    "matrix": matrix_app,
}


def all_apps() -> List[AppDAG]:
    return [b() for b in APP_BUILDERS.values()]
