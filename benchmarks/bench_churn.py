"""Churn recovery + churn-aware planning: recovered/lost/salvaged instances
and the forecast-aware-vs-memoryless placement race.

Two scenario columns:

  * ``churn`` (exponential leave/rejoin streams) for each recovery strategy
    and two schemes:
      - ``lavea`` — no proactive replication, so every device departure that
        catches a task in flight is a potential instance loss: the cleanest
        view of what detection + recovery buys.  ``failover`` and ``replan``
        must strictly reduce P_f vs ``fail_fast`` (the PR-4 gate).
      - ``ibdash`` — Algorithm 1's pf-aware placement + replication absorbs
        this churn level on its own (the paper's core claim).
  * ``correlated`` (per-group shared shocks + rotating scripted maintenance
    windows, ``repro.sim.churn.correlated_churn``) racing registry
    ``ibdash`` against the forecast-aware ``churn_aware`` under
    ``fail_fast`` (raw P_f), ``fail_fast`` + partial-result salvage
    (salvaged-instance counts), and ``replan`` + salvage (everything on —
    both recover every instance, so its service time is the fair E2E
    latency comparison with no survivorship bias).  Gates: ``churn_aware``
    strictly beats ``ibdash`` on P_f, is no worse on E2E latency, and
    salvage strictly reduces ``ibdash``'s losses.

Writes ``BENCH_churn.json``; ``--check BASELINE.json`` exits non-zero when
any gate fails, the recovered-instance rate drops below the committed
baseline (the sim is seeded, so the counts are deterministic — the
tolerance only covers library drift) or replan throughput regresses more
than 3x (wall-clock, so the factor is generous for runner-hardware
variance).

    PYTHONPATH=src python -m benchmarks.bench_churn \
        [--out BENCH_churn.json] [--check benchmarks/BENCH_churn.baseline.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SCHEMES = ("lavea", "ibdash")
RECOVERIES = ("fail_fast", "failover", "replan")
GATED_SCHEME = "lavea"
RATE_TOLERANCE = 0.05          # recovered-rate slack vs baseline
THROUGHPUT_FACTOR = 3.0        # replan/s regression factor (hw-portable-ish)

# correlated column: scheme x (recovery, salvage attempts)
CORR_SCHEMES = ("ibdash", "churn_aware")
CORR_MODES = (
    ("fail_fast", 0),          # raw forecast win (P_f gate)
    ("fail_fast_salvage", 1),  # salvage alone (salvaged-count gate)
    ("replan", 1),             # everything on (E2E latency gate)
)
LATENCY_TOLERANCE = 1.02       # churn_aware svc <= ibdash svc * this


def _config(scenario: str = "churn"):
    from repro.sim import SimConfig

    return SimConfig(
        scenario=scenario, n_cycles=4, instances_per_cycle=400,
        n_devices=100, seed=0,
    )


def measure(scheme: str, recovery: str, profile, cfg, salvage: int = 0) -> dict:
    from repro.api import Orchestrator
    from repro.sim import make_cluster
    from repro.sim.runner import _make_workload, make_churn, policy_for

    cluster = make_cluster(
        profile, scenario=cfg.scenario, n_devices=cfg.n_devices,
        seed=cfg.seed, horizon=cfg.horizon + 30.0,
    )
    churn = make_churn(cfg, cluster)
    orch = Orchestrator(
        cluster, policy_for(scheme, profile, cfg), seed=cfg.seed,
        noise_sigma=cfg.noise_sigma, churn=churn, recovery=recovery,
        salvage=salvage, detection_delay=cfg.detection_delay,
        max_retries=cfg.max_retries,
    )
    apps, times = _make_workload(cfg)
    orch.submit_batch(apps, times)
    orch.drain()
    res = orch.result(cfg.scenario, cfg.horizon)
    stats = dict(orch.stats)
    eng = orch.engine
    touched = stats["recovered"] + stats["lost"]
    row = {
        "prob_failure": res.prob_failure,
        "avg_service_time": res.avg_service_time,
        "recovered": stats["recovered"],
        "lost": stats["lost"],
        "recovered_rate": stats["recovered"] / touched if touched else 1.0,
        "replica_deaths": stats["replica_deaths"],
        "device_down": stats["device_down"],
        "device_up": stats["device_up"],
        "task_failovers": stats["task_failovers"],
        "replans": stats["replans"],
        "salvages": stats["salvages"],
        "salvaged": stats["salvaged"],
        "replan_time_s": eng.replan_time,
        "replans_per_sec": (
            stats["replans"] / eng.replan_time if eng.replan_time > 0 else 0.0
        ),
    }
    return row


def full_report() -> dict:
    from repro.sim import make_profile

    cfg = _config()
    corr_cfg = _config("correlated_churn")
    profile = make_profile(seed=cfg.seed)
    report = {
        "config": {
            "scenario": cfg.scenario, "n_cycles": cfg.n_cycles,
            "instances_per_cycle": cfg.instances_per_cycle,
            "n_devices": cfg.n_devices, "seed": cfg.seed,
            "mean_downtime": cfg.mean_downtime,
            "detection_delay": cfg.detection_delay,
            "max_retries": cfg.max_retries,
            "correlated": {
                "churn_groups": corr_cfg.churn_groups,
                "shock_rate": corr_cfg.shock_rate,
                "maintenance_period": corr_cfg.maintenance_period,
                "maintenance_duration": corr_cfg.maintenance_duration,
            },
        },
        "results": {
            scheme: {
                recovery: measure(scheme, recovery, profile, cfg)
                for recovery in RECOVERIES
            }
            for scheme in SCHEMES
        },
        "correlated": {
            scheme: {
                mode: measure(
                    scheme, mode.replace("_salvage", ""), profile, corr_cfg,
                    salvage=salvage,
                )
                for mode, salvage in CORR_MODES
            }
            for scheme in CORR_SCHEMES
        },
    }
    return report


def check(report: dict, baseline_path: str) -> int:
    """Gate the PR's acceptance properties against the committed baseline:

    * churn must actually bite the gated scheme under ``fail_fast``;
    * ``failover`` and ``replan`` must strictly reduce P_f vs ``fail_fast``
      and keep their recovered-instance rate within RATE_TOLERANCE of the
      baseline (counts are deterministic given the seed);
    * replan throughput must stay within THROUGHPUT_FACTOR of baseline;
    * on the correlated scenario, ``churn_aware`` must strictly beat
      registry ``ibdash`` on P_f (fail_fast rows), be no worse on E2E
      latency (replan rows, where both recover everything), and salvage
      must strictly reduce ``ibdash``'s instance losses while actually
      salvaging instances.
    """
    with open(baseline_path) as f:
        baseline = json.load(f)
    failures = []
    rows = report["results"][GATED_SCHEME]
    base_rows = baseline["results"][GATED_SCHEME]
    if rows["fail_fast"]["lost"] == 0:
        failures.append(
            f"{GATED_SCHEME}/fail_fast: no instances lost — churn scenario "
            "no longer exercises recovery"
        )
    for recovery in ("failover", "replan"):
        got, base = rows[recovery], base_rows[recovery]
        if got["prob_failure"] >= rows["fail_fast"]["prob_failure"]:
            failures.append(
                f"{GATED_SCHEME}/{recovery}: P_f {got['prob_failure']:.4f} "
                f">= fail_fast {rows['fail_fast']['prob_failure']:.4f}"
            )
        floor = base["recovered_rate"] - RATE_TOLERANCE
        if got["recovered_rate"] < floor:
            failures.append(
                f"{GATED_SCHEME}/{recovery}: recovered rate "
                f"{got['recovered_rate']:.3f} < {floor:.3f} "
                f"(baseline {base['recovered_rate']:.3f} - {RATE_TOLERANCE})"
            )
    got_tp = rows["replan"]["replans_per_sec"]
    base_tp = base_rows["replan"]["replans_per_sec"]
    if base_tp > 0 and got_tp < base_tp / THROUGHPUT_FACTOR:
        failures.append(
            f"{GATED_SCHEME}/replan: {got_tp:.1f} replans/s < "
            f"{base_tp / THROUGHPUT_FACTOR:.1f} "
            f"(baseline {base_tp:.1f} / {THROUGHPUT_FACTOR})"
        )

    # -- correlated scenario: the churn-aware acceptance gates ----------------
    corr = report["correlated"]
    ib, ca = corr["ibdash"], corr["churn_aware"]
    if ib["fail_fast"]["lost"] == 0:
        failures.append(
            "correlated/ibdash/fail_fast: no instances lost — the "
            "correlated scenario no longer stresses placement"
        )
    if ca["fail_fast"]["prob_failure"] >= ib["fail_fast"]["prob_failure"]:
        failures.append(
            "correlated: churn_aware P_f "
            f"{ca['fail_fast']['prob_failure']:.4f} >= ibdash "
            f"{ib['fail_fast']['prob_failure']:.4f} — the forecast no "
            "longer beats memoryless pricing"
        )
    if ca["replan"]["prob_failure"] > ib["replan"]["prob_failure"]:
        failures.append(
            "correlated/replan: churn_aware P_f "
            f"{ca['replan']['prob_failure']:.4f} > ibdash "
            f"{ib['replan']['prob_failure']:.4f}"
        )
    lat_ca = ca["replan"]["avg_service_time"]
    lat_ib = ib["replan"]["avg_service_time"]
    if lat_ca > lat_ib * LATENCY_TOLERANCE:
        failures.append(
            f"correlated/replan: churn_aware E2E latency {lat_ca:.3f}s > "
            f"ibdash {lat_ib:.3f}s * {LATENCY_TOLERANCE}"
        )
    salv = ib["fail_fast_salvage"]
    if salv["salvaged"] == 0:
        failures.append(
            "correlated/ibdash/fail_fast_salvage: no instance was salvaged"
        )
    if salv["lost"] >= ib["fail_fast"]["lost"]:
        failures.append(
            f"correlated/ibdash: salvage did not reduce losses "
            f"({salv['lost']} >= {ib['fail_fast']['lost']})"
        )
    for msg in failures:
        print(f"REGRESSION {msg}", file=sys.stderr)
    return 1 if failures else 0


def run(ctx) -> None:
    """benchmarks.run entry point: emit CSV rows + write BENCH_churn.json."""
    report = full_report()
    for scheme, rows in report["results"].items():
        for recovery, row in rows.items():
            key = f"churn_{scheme}_{recovery}"
            ctx.emit(f"{key}_pf", row["prob_failure"])
            ctx.emit(f"{key}_recovered", row["recovered"])
            ctx.emit(f"{key}_lost", row["lost"])
    for scheme, rows in report["correlated"].items():
        for mode, row in rows.items():
            key = f"corr_{scheme}_{mode}"
            ctx.emit(f"{key}_pf", row["prob_failure"])
            ctx.emit(f"{key}_svc", row["avg_service_time"])
            ctx.emit(f"{key}_salvaged", row["salvaged"])
    ctx.emit(
        "churn_replan_per_sec",
        report["results"][GATED_SCHEME]["replan"]["replans_per_sec"],
    )
    from .common import write_current_run

    write_current_run("churn", report)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_churn.json")
    ap.add_argument("--check", default=None,
                    help="baseline json; exit 1 on recovery regression")
    args = ap.parse_args()
    report = full_report()
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    for scheme, rows in report["results"].items():
        for recovery, row in rows.items():
            print(
                f"{scheme:8s} {recovery:10s}  P_f {row['prob_failure']:.4f}  "
                f"recovered {row['recovered']:4d}  lost {row['lost']:4d}  "
                f"deaths {row['replica_deaths']:4d}  "
                f"replans {row['replans']:3d} "
                f"({row['replans_per_sec']:7.1f}/s)"
            )
    print("-- correlated (shared shocks + maintenance windows) --")
    for scheme, rows in report["correlated"].items():
        for mode, row in rows.items():
            print(
                f"{scheme:12s} {mode:18s}  P_f {row['prob_failure']:.4f}  "
                f"svc {row['avg_service_time']:.3f}s  "
                f"lost {row['lost']:4d}  salvaged {row['salvaged']:3d}"
            )
    if args.check:
        sys.exit(check(report, args.check))


if __name__ == "__main__":
    main()
