"""Roofline terms per (arch x shape) from the dry-run grid (§Roofline)."""
import json
import os


def run(ctx):
    from repro.launch.roofline import build_table

    path = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json")
    if not os.path.exists(path):
        ctx.emit("roofline_skipped", 0, "dryrun_results.json missing — run "
                 "python -m repro.launch.dryrun --all --mesh both --out dryrun_results.json")
        return
    with open(path) as f:
        results = json.load(f)
    rows = build_table(results, mesh="single")
    for r in rows:
        ctx.emit(
            f"roofline_{r['arch']}_{r['shape']}",
            r["bound_s"],
            f"dom={r['dominant']} comp={r['compute_s']:.3g}s "
            f"mem={r['memory_s']:.3g}s coll={r['collective_s']:.3g}s "
            f"useful={r['useful_ratio']:.2f} mfu<={r['mfu_bound']:.2f}",
        )
    n_by = {}
    for r in rows:
        n_by[r["dominant"]] = n_by.get(r["dominant"], 0) + 1
    for k, v in sorted(n_by.items()):
        ctx.emit(f"roofline_dominant_{k}", v, "cells")
