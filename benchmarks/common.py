"""Shared benchmark context: one simulation grid reused by the Fig.8/9
benches, CSV row helpers, the --full switch (paper-scale protocol), and the
baseline-regeneration CLI:

    PYTHONPATH=src python -m benchmarks.common --update-baseline place churn stream

re-runs each named gated bench's ``full_report()`` and overwrites its
committed ``benchmarks/BENCH_<name>.baseline.json``.
"""
from __future__ import annotations

import json
import os
import sys
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"

SCHEMES = ("ibdash", "lats", "lavea", "petrel", "round_robin", "random")
SCENARIOS = ("ced", "ped", "mix")


def sim_config(**kw):
    from repro.api import SimConfig

    base = dict(
        n_cycles=20 if FULL else 8,
        instances_per_cycle=1000 if FULL else 400,
        seed=0,
    )
    base.update(kw)
    return SimConfig(**base)


@dataclass
class Ctx:
    """Lazily-computed shared state across benches."""

    _grid: Optional[Dict] = None
    _profile: object = None
    rows: List[Tuple[str, float, str]] = field(default_factory=list)

    @property
    def profile(self):
        if self._profile is None:
            from repro.api import make_profile

            self._profile = make_profile(seed=0)
        return self._profile

    def grid(self) -> Dict:
        """(scheme, scenario) -> SimResult, computed once.

        Runs through the unified ``repro.api`` façade (registry policies +
        online Orchestrator), like every other consumer."""
        if self._grid is None:
            from dataclasses import replace

            from repro.api import run_one

            out = {}
            for scen in SCENARIOS:
                cfg = sim_config(scenario=scen)
                for scheme in SCHEMES:
                    t0 = time.time()
                    out[(scheme, scen)] = run_one(scheme, cfg, self.profile)
                    print(f"# sim {scheme}/{scen} done in {time.time()-t0:.1f}s",
                          file=sys.stderr)
            self._grid = out
        return self._grid

    def emit(self, name: str, value: float, derived: str = "") -> None:
        self.rows.append((name, value, derived))
        print(f"{name},{value:.6g},{derived}")


# Benches whose full_report() is gated in CI against a committed baseline.
GATED_BENCHES = ("place", "churn", "stream", "obs")


def write_current_run(name: str, report: dict) -> str:
    """Write a gated bench's current run to the repo-root
    ``BENCH_<name>.json`` — the committed perf-trajectory artifact (one
    snapshot per PR, next to the code it measured), distinct from the
    regression baseline under ``benchmarks/``."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    return path


def update_baselines(names: List[str]) -> None:
    """Regenerate ``benchmarks/BENCH_<name>.baseline.json`` for each gated
    bench by re-running its ``full_report()`` (the authoritative shape the
    bench's ``check()`` consumes).  The same report is also written to the
    repo-root ``BENCH_<name>.json`` trajectory artifact, so both committed
    files always describe the same run."""
    import importlib

    here = os.path.dirname(__file__)
    for name in names:
        if name not in GATED_BENCHES:
            raise SystemExit(
                f"unknown gated bench {name!r} (choose from {GATED_BENCHES})"
            )
        mod = importlib.import_module(f"benchmarks.bench_{name}")
        print(f"# regenerating {name} baseline ...", file=sys.stderr)
        t0 = time.time()
        report = mod.full_report()
        path = os.path.join(here, f"BENCH_{name}.baseline.json")
        with open(path, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")
        current = write_current_run(name, report)
        print(f"# wrote {path} + {current} in {time.time()-t0:.1f}s",
              file=sys.stderr)


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--update-baseline", nargs="*", metavar="BENCH", default=None,
        help="regenerate the committed baseline json for these gated "
             "benches (no names = all of them)",
    )
    args = ap.parse_args()
    if args.update_baseline is None:
        ap.error("nothing to do (pass --update-baseline [BENCH ...])")
    update_baselines(args.update_baseline or list(GATED_BENCHES))


if __name__ == "__main__":
    main()
