"""Beyond-paper: IBDASH as a serving-fleet scheduler (latency + preemption)."""
import numpy as np


def run(ctx):
    from repro.serve.scheduler import ServingFleet, serving_interference_model

    im = serving_interference_model()
    base = {}
    for pol in ("ibdash", "petrel", "lavea", "round_robin"):
        fleet = ServingFleet(im, policy=pol, n_replicas=16, seed=0)
        res = fleet.run(n_requests=600, arrival_window=8.0, seed=1)
        base[pol] = res
        ctx.emit(f"serve_{pol}_latency_ms", res.avg_service_time * 1e3, "")
        ctx.emit(f"serve_{pol}_failrate", res.prob_failure, "")
    best_l = min(r.avg_service_time for k, r in base.items() if k != "ibdash")
    ctx.emit("serve_ibdash_latency_gain",
             100 * (1 - base["ibdash"].avg_service_time / best_l),
             "% vs best baseline policy")
