"""Benchmark modules: one per paper table/figure (see run.py)."""
