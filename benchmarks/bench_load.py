"""Fig. 10 — load distribution across 8 devices (one per class)."""
import numpy as np

from .common import SCHEMES, sim_config


def run(ctx):
    from repro.sim import run_one

    cfg = sim_config(n_devices=8, n_cycles=1, instances_per_cycle=200,
                     scenario="mix")
    for scheme in SCHEMES:
        res = run_one(scheme, cfg, ctx.profile)
        load = res.load_per_device.astype(float)
        cv = float(load.std() / max(load.mean(), 1e-9))
        top = int(np.argmax(load))
        ctx.emit(f"fig10_load_cv_{scheme}", cv,
                 f"max on ED{top} ({int(load[top])} of {int(load.sum())} tasks)")
    # paper: LaTS concentrates (high CV), IBDASH/LAVEA spread (low CV)
