"""Benchmark harness: one module per paper table/figure (+ beyond-paper).

Prints ``name,value,derived`` CSV rows.  Set REPRO_BENCH_FULL=1 for the
paper-scale protocol (20 cycles x 1000 instances, fine-grained sweeps).

    PYTHONPATH=src python -m benchmarks.run [bench ...]
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from . import (
    bench_alpha_gamma,
    bench_availability,
    bench_churn,
    bench_failure,
    bench_interference,
    bench_load,
    bench_microscopic,
    bench_obs,
    bench_place,
    bench_profiles,
    bench_roofline,
    bench_service_time,
    bench_serving,
    bench_serving_shard,
    bench_stream,
)
from .common import Ctx

BENCHES = {
    "interference": bench_interference,   # Fig. 2 / Fig. 4
    "profiles": bench_profiles,           # Table III / Fig. 5
    "availability": bench_availability,   # Fig. 7 / Table IV
    "service_time": bench_service_time,   # Fig. 8
    "failure": bench_failure,             # Fig. 9
    "load": bench_load,                   # Fig. 10
    "microscopic": bench_microscopic,     # Fig. 11
    "alpha_gamma": bench_alpha_gamma,     # Fig. 12
    "place": bench_place,                 # beyond-paper burst placement
    "churn": bench_churn,                 # beyond-paper churn recovery
    "serving": bench_serving,             # beyond-paper fleet policies
    "roofline": bench_roofline,           # §Roofline (dry-run grid)
    "serving_shard": bench_serving_shard, # beyond-paper TP serving sharding
    "stream": bench_stream,               # beyond-paper always-on service
    "obs": bench_obs,                     # observability overhead + validity
}


def main() -> None:
    names = sys.argv[1:] or list(BENCHES)
    ctx = Ctx()
    print("name,value,derived")
    for name in names:
        mod = BENCHES[name]
        t0 = time.time()
        print(f"# === {name} ===", file=sys.stderr)
        mod.run(ctx)
        print(f"# {name} took {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
