"""Fig. 12 — joint-optimisation alpha sweep and replication-degree gamma sweep."""
import numpy as np

from .common import FULL, sim_config


def run(ctx):
    from repro.sim import sweep_alpha, sweep_gamma

    alphas = np.arange(0.0, 1.01, 0.01) if FULL else (0.0, 0.25, 0.5, 0.75, 1.0)
    # PED so predicted failure actually crosses beta inside the window
    rows = sweep_alpha(alphas, sim_config(scenario="ped"))
    for a, svc, pf in rows:
        ctx.emit(f"fig12a_alpha_{a:.2f}_service", svc, f"pf={pf:.4f}")
    # trend: more weight on latency (alpha up) -> service time down, pf up
    svcs = [r[1] for r in rows]
    pfs = [r[2] for r in rows]
    ctx.emit("fig12a_service_trend", svcs[0] - svcs[-1],
             "s saved from alpha=0 to alpha=1 (>0 expected)")
    ctx.emit("fig12a_pf_trend", pfs[-1] - pfs[0],
             "P_f increase from alpha=0 to alpha=1 (>=0 expected)")

    gammas = (0, 1, 2, 3, 4, 6, 8) if FULL else (0, 1, 3, 6)
    rows = sweep_gamma(gammas, sim_config(scenario="ped"))
    for g, svc, pf, nrep in rows:
        ctx.emit(f"fig12b_gamma_{g}_pf", pf, f"svc={svc:.3f}s reps={nrep:.2f}")
    ctx.emit("fig12b_pf_drop_0_to_max", rows[0][2] - rows[-1][2],
             "P_f reduction from replication (paper: saturates ~6)")
