"""Fig. 9 — probability of failure: 6 schemes x 3 scenarios (+ headline)."""
import numpy as np

from .common import SCENARIOS, SCHEMES


def run(ctx):
    grid = ctx.grid()
    for scen in SCENARIOS:
        for scheme in SCHEMES:
            r = grid[(scheme, scen)]
            ctx.emit(f"fig9_pf_{scen}_{scheme}", r.prob_failure, "")
    rels = []
    for scen in SCENARIOS:
        ib = grid[("ibdash", scen)].prob_failure
        best = min(grid[(s, scen)].prob_failure for s in SCHEMES if s != "ibdash")
        rel = 100 * (1 - ib / max(best, 1e-9))
        rels.append(rel)
        ctx.emit(f"fig9_ibdash_vs_best_{scen}", rel, "% P_f reduction")
        # paper also reports IBDASH vs LaTS per scenario (29.7/58.5/34 %)
        lats = grid[("lats", scen)].prob_failure
        ctx.emit(f"fig9_ibdash_vs_lats_{scen}",
                 100 * (1 - ib / max(lats, 1e-9)), "% vs LaTS")
    ctx.emit("fig9_ibdash_vs_best_avg", float(np.mean(rels)),
             "% avg reduction (paper: 41% vs best baseline)")
    # vs the strongest NON-LaTS baseline (the load-balancing family)
    rels2 = []
    for scen in SCENARIOS:
        ib = grid[("ibdash", scen)].prob_failure
        best = min(grid[(s, scen)].prob_failure
                   for s in ("lavea", "petrel", "round_robin", "random"))
        rels2.append(100 * (1 - ib / max(best, 1e-9)))
    ctx.emit("fig9_ibdash_vs_best_nonlats_avg", float(np.mean(rels2)), "%")
