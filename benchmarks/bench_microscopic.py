"""Fig. 11 — microscopic view: 200 instances, per-instance service/failure,
replication ramping with device age."""
import numpy as np

from .common import sim_config


def run(ctx):
    from repro.sim import run_one

    # 200 instances arriving within 1.5 s, mixed scenario, 8 devices
    cfg = sim_config(n_devices=8, n_cycles=1, instances_per_cycle=200,
                     scenario="ped")
    for scheme in ("ibdash", "lats", "petrel"):
        res = run_one(scheme, cfg, ctx.profile)
        svc = [r.service_time for r in res.instances if not r.failed]
        ctx.emit(f"fig11_{scheme}_p50_service", float(np.median(svc)), "s")
        ctx.emit(f"fig11_{scheme}_p95_service",
                 float(np.percentile(svc, 95)), "s")
        ctx.emit(f"fig11_{scheme}_failures", float(res.prob_failure), "")

    # replication ramps with predicted failure (late placements replicate
    # more): compare replicas in the first vs last simulated cycle
    cfg2 = sim_config(scenario="ped", n_cycles=6, instances_per_cycle=200)
    res = run_one("ibdash", cfg2, ctx.profile)
    split = cfg2.horizon / 2
    early = np.mean([r.n_replicas for r in res.instances if r.arrival < split])
    late = np.mean([r.n_replicas for r in res.instances if r.arrival >= split])
    ctx.emit("fig11_ibdash_replicas_early", float(early), "per instance")
    ctx.emit("fig11_ibdash_replicas_late", float(late),
             "per instance (paper: replication increases late)")
