"""Observability overhead + trace-validity gate (repro.obs).

Three sections:

  * ``placement`` — fused batched placement throughput (B = 64, the
    bench_place protocol) with NO tracer anywhere in sight: the number the
    PR-8 era gated.  ``check()`` holds it within the standard wall-clock
    regression factor of this bench's own baseline AND of the committed
    ``BENCH_place.baseline.json`` batched_pps, so threading the tracer
    through the engine cannot tax the tracing-off pipeline unnoticed.
  * ``overhead`` — the same seeded churn run end-to-end with tracing off
    and tracing on.  Tracing-off instances/sec is gated like any other
    throughput column; tracing-on overhead is RECORDED (``overhead_pct``)
    so the trajectory is visible across PRs, and the two runs are asserted
    bit-identical (the observer effect is a correctness failure, not a
    perf number).
  * ``validation`` — the acceptance scenario: a correlated-churn + salvage
    run with tracing on must export a structurally valid Chrome
    ``trace_event`` JSON whose instance events alone reproduce the
    engine's conservation ledger ``admitted == completed + lost + shed``
    exactly, and an attribution report carrying per-stage critical-path
    aggregates and per-policy latency / P_f calibration.  These gates are
    exact and hardware-independent.

Writes ``BENCH_obs.json``; ``--check BASELINE.json`` exits non-zero on
any validity failure or throughput regression.

    PYTHONPATH=src python -m benchmarks.bench_obs \\
        [--out BENCH_obs.json] [--check benchmarks/BENCH_obs.baseline.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

PLACE_B = 64                   # bench_place's middle batch size
THROUGHPUT_FACTOR = 3.0        # wall-clock regression factor (CI standard)
OVERHEAD_REPS = 3              # timed repetitions per tracing mode


def _overhead_cfg(trace: bool):
    from repro.api import SimConfig

    return SimConfig(scenario="churn", n_cycles=2, instances_per_cycle=200,
                     seed=5, n_devices=50, recovery="failover", trace=trace)


def _validation_cfg():
    """Correlated churn hot enough to kill instances outright, replan +
    salvage on — the whole span vocabulary fires (mirrors tests/test_obs)."""
    from repro.api import SimConfig

    return SimConfig(scenario="correlated_churn", n_cycles=2,
                     instances_per_cycle=60, seed=3, n_devices=12,
                     recovery="replan", salvage=2, shock_rate=0.2,
                     mean_downtime=30.0, gamma=1, max_retries=1, trace=True)


def measure_placement(profile) -> dict:
    """Pure planning throughput, bench_place protocol at B=64 — the PR-8
    number the tracing work must leave untouched."""
    from repro.api import orchestrate_batch
    from repro.sim import SimConfig, make_cluster
    from repro.sim.apps import APP_BUILDERS
    from repro.sim.runner import policy_for

    import numpy as np

    rng = np.random.default_rng(1)
    builders = list(APP_BUILDERS.values())
    apps = [builders[int(rng.integers(len(builders)))]().relabel(f"#{i}")
            for i in range(PLACE_B)]
    cluster = make_cluster(profile, scenario="mix", n_devices=100, seed=0,
                           horizon=400.0)
    pol = policy_for("ibdash", profile, SimConfig(seed=0))
    orchestrate_batch(apps, cluster, pol)          # warm the jitted kernels
    reps = max(1, 2000 // PLACE_B)
    t0 = time.perf_counter()
    for _ in range(reps):
        orchestrate_batch(apps, cluster, pol)
    dt = (time.perf_counter() - t0) / reps
    return {"B": PLACE_B, "batched_pps": PLACE_B / dt}


def measure_overhead(profile) -> dict:
    from repro.sim import run_one

    def timed(trace: bool):
        best, res = float("inf"), None
        for _ in range(OVERHEAD_REPS):
            t0 = time.perf_counter()
            res = run_one("ibdash", _overhead_cfg(trace), profile)
            best = min(best, time.perf_counter() - t0)
        return best, res

    wall_off, res_off = timed(False)
    wall_on, res_on = timed(True)
    # identical seeded runs: tracing must not perturb a single outcome
    same = (
        [(r.app, r.finished, r.failed) for r in res_off.instances]
        == [(r.app, r.finished, r.failed) for r in res_on.instances]
    )
    n = len(res_off.instances)
    return {
        "n_instances": n,
        "wall_off_s": wall_off,
        "wall_on_s": wall_on,
        "instances_per_sec_off": n / wall_off,
        "instances_per_sec_on": n / wall_on,
        "overhead_pct": 100.0 * (wall_on - wall_off) / wall_off,
        "n_spans": len(res_on.trace.spans),
        "bit_identical": same,
    }


def measure_validation(profile) -> dict:
    from repro.obs import (
        attribution_report,
        ledger_from_trace,
        to_chrome_trace,
        validate_chrome_trace,
    )
    from repro.sim import run_one

    res = run_one("ibdash", _validation_cfg(), profile)
    tr = res.trace
    doc = to_chrome_trace(tr)
    n_events = validate_chrome_trace(doc)
    led = ledger_from_trace(doc)
    counts = tr.outcome_counts()
    rep = attribution_report(tr, top_k=3)
    pol = rep["calibration"]["policy"].get("ibdash", {})
    return {
        "n_instances": tr.n_instances,
        "n_spans": len(tr.spans),
        "n_trace_events": n_events,
        "ledger": led,
        "ledger_round_trip": (
            led["admitted"] == led["completed"] + led["lost"] + led["shed"]
            and led["completed"] == counts.get("completed", 0)
            and led["lost"] == counts.get("lost", 0)
        ),
        "lost": led["lost"],
        "salvage_events": len(tr.by_kind("salvage")),
        "replan_events": len(tr.by_kind("replan")),
        "critical_path_n": rep["critical_path"]["n"],
        "latency_bias_s": pol.get("latency", {}).get("bias"),
        "pred_p_fail": pol.get("p_fail", {}).get("pred_mean"),
        "empirical_p_fail": pol.get("p_fail", {}).get("empirical"),
    }


def full_report() -> dict:
    from repro.api import make_profile

    profile = make_profile(seed=0)
    return {
        "config": {
            "place_B": PLACE_B,
            "overhead": {"scenario": "churn", "n_instances": 400},
            "validation": {"scenario": "correlated_churn", "salvage": 2},
        },
        "placement": measure_placement(profile),
        "overhead": measure_overhead(profile),
        "validation": measure_validation(profile),
    }


def check(report: dict, baseline_path: str) -> int:
    """Exact validity gates + wall-clock throughput gates.

    Tracing-off throughput is held within THROUGHPUT_FACTOR of this
    bench's own baseline; placement throughput additionally within the
    same factor of the committed PR-8 ``BENCH_place.baseline.json``."""
    with open(baseline_path) as f:
        baseline = json.load(f)
    failures = []

    val = report["validation"]
    if not val["ledger_round_trip"]:
        failures.append(
            f"trace ledger does not round-trip the engine counters: "
            f"{val['ledger']}"
        )
    if val["lost"] <= 0 or val["salvage_events"] <= 0:
        failures.append(
            "validation scenario no longer exercises loss + salvage "
            f"(lost={val['lost']}, salvages={val['salvage_events']})"
        )
    if val["critical_path_n"] <= 0:
        failures.append("attribution report covers no completed instances")
    if val["latency_bias_s"] is None or val["pred_p_fail"] is None:
        failures.append("per-policy calibration rows missing from report")

    ov = report["overhead"]
    if not ov["bit_identical"]:
        failures.append("tracing perturbed the seeded run (observer effect)")
    base_ips = baseline["overhead"]["instances_per_sec_off"]
    if ov["instances_per_sec_off"] < base_ips / THROUGHPUT_FACTOR:
        failures.append(
            f"tracing-off engine throughput "
            f"{ov['instances_per_sec_off']:.0f} inst/s < "
            f"{base_ips / THROUGHPUT_FACTOR:.0f} "
            f"(baseline {base_ips:.0f} / {THROUGHPUT_FACTOR})"
        )

    got_pps = report["placement"]["batched_pps"]
    base_pps = baseline["placement"]["batched_pps"]
    if got_pps < base_pps / THROUGHPUT_FACTOR:
        failures.append(
            f"placement throughput {got_pps:.0f} pl/s < "
            f"{base_pps / THROUGHPUT_FACTOR:.0f} "
            f"(baseline {base_pps:.0f} / {THROUGHPUT_FACTOR})"
        )
    place_base = os.path.join(
        os.path.dirname(baseline_path), "BENCH_place.baseline.json"
    )
    if os.path.exists(place_base):
        with open(place_base) as f:
            pr8 = json.load(f)
        pr8_pps = pr8["results"][str(PLACE_B)]["batched_pps"]
        if got_pps < pr8_pps / THROUGHPUT_FACTOR:
            failures.append(
                f"placement throughput {got_pps:.0f} pl/s < "
                f"{pr8_pps / THROUGHPUT_FACTOR:.0f} (PR-8 place baseline "
                f"{pr8_pps:.0f} / {THROUGHPUT_FACTOR})"
            )

    for msg in failures:
        print(f"REGRESSION {msg}", file=sys.stderr)
    return 1 if failures else 0


def run(ctx) -> None:
    """benchmarks.run entry point: emit CSV rows + write BENCH_obs.json."""
    report = full_report()
    ctx.emit("obs_batched_pps", report["placement"]["batched_pps"])
    ctx.emit("obs_instances_per_sec_off",
             report["overhead"]["instances_per_sec_off"])
    ctx.emit("obs_overhead_pct", report["overhead"]["overhead_pct"])
    ctx.emit("obs_trace_events", report["validation"]["n_trace_events"])
    from .common import write_current_run

    write_current_run("obs", report)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_obs.json")
    ap.add_argument("--check", default=None,
                    help="baseline json; exit 1 on a validity failure or "
                         "throughput regression")
    args = ap.parse_args()
    report = full_report()
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    pl, ov, val = report["placement"], report["overhead"], report["validation"]
    print(f"placement  B={pl['B']}  {pl['batched_pps']:10.1f} pl/s (no tracer)")
    print(f"overhead   off {ov['instances_per_sec_off']:8.1f} inst/s  "
          f"on {ov['instances_per_sec_on']:8.1f} inst/s  "
          f"overhead {ov['overhead_pct']:+5.1f}%  "
          f"({ov['n_spans']} spans, identical={ov['bit_identical']})")
    print(f"validation {val['n_instances']} instances -> "
          f"{val['n_trace_events']} trace events  ledger {val['ledger']}  "
          f"round-trip={val['ledger_round_trip']}  "
          f"salvages={val['salvage_events']} replans={val['replan_events']}")
    if val["latency_bias_s"] is not None:
        print(f"calibration ibdash latency bias {val['latency_bias_s']:+.3f}s  "
              f"P_f pred {val['pred_p_fail']:.3f} "
              f"emp {val['empirical_p_fail']:.3f}")
    if args.check:
        sys.exit(check(report, args.check))


if __name__ == "__main__":
    main()
