"""Burst-placement throughput: scalar per-task loop vs one fused batched
call (the PR-2 batched placement API).

Plans B application instances arriving simultaneously on the paper's
100-device mix fleet with IBDASH, through both paths:

  * scalar  — ``orchestrate(app, ..., batched=False)`` per instance: the
    PR-1 per-task ``decide(ctx)`` loop.
  * batched — ``orchestrate_batch(apps, ...)``: one deduplicated
    ``BatchedPolicyContext`` + one fused ``decide_batch`` call per
    wave-stage.

Both paths are pure planning against the same snapshot and are bit-identical
(asserted here on every run).  A second section runs the asymmetric 3-tier
``multi_tier`` fleet with the ``tier_escalation`` policy, so the report also
records placement throughput under the tier-aware bottleneck-link cost
model.  A third section sweeps FLEET SIZE (1k / 10k / 100k devices) over
the factorized snapshot path with the dense ``(D, D)`` accessor tripwired —
reintroducing the dense matrix anywhere in wave planning fails the bench
outright rather than just slowing it.  Writes ``BENCH_place.json`` with
placements/sec at B ∈ {1, 64, 1000} plus the fleet-sweep columns;
``--check BASELINE.json`` exits non-zero on a >2x regression of the
batched-vs-scalar speedup ratio, a missing/failed fleet-sweep point, or a
>3x regression of the sweep's 1k/100k throughput-scaling ratio against the
committed baseline (used by CI; ratios are gated rather than absolute
throughput so the check is portable across runner hardware).

    PYTHONPATH=src python -m benchmarks.bench_place \
        [--out BENCH_place.json] [--check benchmarks/BENCH_place.baseline.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

BATCH_SIZES = (1, 64, 1000)
REGRESSION_FACTOR = 2.0
FLEET_SIZES = (1_000, 10_000, 100_000)
# the fleet sweep gates the SHAPE of the scaling curve (pps@1k / pps@100k),
# which is hardware-portable but noisier than the single-fleet speedup ratio
SWEEP_REGRESSION_FACTOR = 3.0


def _workload(B: int, seed: int = 1):
    from repro.sim.apps import APP_BUILDERS

    builders = list(APP_BUILDERS.values())
    rng = np.random.default_rng(seed)
    return [
        builders[int(rng.integers(len(builders)))]().relabel(f"#{i}")
        for i in range(B)
    ]


def _same_plans(plans_a, plans_b) -> None:
    for a, b in zip(plans_a, plans_b):
        assert a.placement.feasible == b.placement.feasible
        assert a.placement.est_latency == b.placement.est_latency
        for k, tp in a.placement.tasks.items():
            other = b.placement.tasks[k]
            assert [r.did for r in tp.replicas] == [r.did for r in other.replicas]


def measure(
    scheme: str = "ibdash",
    n_devices: int = 100,
    seed: int = 0,
    scenario: str = "mix",
    latency_budget: float = float("inf"),
):
    from repro.api import orchestrate, orchestrate_batch
    from repro.sim import SimConfig, make_cluster, make_profile
    from repro.sim.runner import policy_for

    cfg = SimConfig(seed=seed, latency_budget=latency_budget)
    profile = make_profile(seed=seed)
    cluster = make_cluster(
        profile, scenario=scenario, n_devices=n_devices, seed=seed,
        horizon=400.0,
    )
    results = {}
    for B in BATCH_SIZES:
        apps = _workload(B)
        # warm up the jitted kernels at this wave shape, and assert parity
        pol = policy_for(scheme, profile, cfg)
        plans_b = orchestrate_batch(apps, cluster, pol)
        pol = policy_for(scheme, profile, cfg)
        _same_plans(
            plans_b,
            [orchestrate(app, cluster, 0.0, pol, batched=False) for app in apps],
        )

        reps = max(1, 2000 // B)
        pol = policy_for(scheme, profile, cfg)
        t0 = time.perf_counter()
        for _ in range(reps):
            orchestrate_batch(apps, cluster, pol)
        batched_s = (time.perf_counter() - t0) / reps

        pol = policy_for(scheme, profile, cfg)
        t0 = time.perf_counter()
        for _ in range(reps):
            for app in apps:
                orchestrate(app, cluster, 0.0, pol, batched=False)
        scalar_s = (time.perf_counter() - t0) / reps

        results[str(B)] = {
            "scalar_pps": B / scalar_s,
            "batched_pps": B / batched_s,
            "speedup": scalar_s / batched_s,
        }
    return {
        "scheme": scheme,
        "scenario": scenario,
        "n_devices": n_devices,
        "n_tasks_per_instance": float(np.mean([a.n_tasks for a in _workload(64)])),
        "results": results,
    }


def _forbid_dense(*_a, **_k):
    raise AssertionError(
        "dense (D, D) link matrix materialized during the fleet sweep — "
        "the factorized snapshot path must never build it"
    )


def fleet_sweep(
    scheme: str = "ibdash",
    B: int = 16,
    sizes=FLEET_SIZES,
    seed: int = 0,
) -> dict:
    """Batched placement throughput vs fleet size on the factorized
    snapshot path (multi-tier fleets, so the backhaul factor is live).

    Every cluster's dense ``link_bw`` accessor is replaced with a tripwire:
    the sweep COMPLETING is the proof that no ``(D, D)`` array was
    materialized anywhere in wave planning, at 100k devices included.
    T_alloc uses coarse buckets (dt=0.5, horizon=20) so the occupancy
    tensor — the one intentionally O(D x N x buckets) structure — stays a
    few hundred MB at 100k devices."""
    from repro.api import orchestrate_batch
    from repro.sim import SimConfig, make_cluster, make_profile
    from repro.sim.runner import policy_for

    profile = make_profile(seed=seed)
    cfg = SimConfig(seed=seed)
    apps = _workload(B)
    results = {}
    for D in sizes:
        cluster = make_cluster(
            profile, scenario="multi_tier", n_devices=D, seed=seed,
            horizon=20.0, dt=0.5,
        )
        cluster.link_bw = _forbid_dense
        pol = policy_for(scheme, profile, cfg)
        orchestrate_batch(apps, cluster, pol)     # warm the jitted kernels
        reps = 5 if D <= 10_000 else 2
        pol = policy_for(scheme, profile, cfg)
        t0 = time.perf_counter()
        for _ in range(reps):
            orchestrate_batch(apps, cluster, pol)
        wave_s = (time.perf_counter() - t0) / reps
        results[str(D)] = {"pps": B / wave_s, "wave_s": wave_s}
    return {"scheme": scheme, "B": B, "results": results}


def full_report() -> dict:
    """The paper's mix fleet with IBDASH, plus the multi-tier fleet (the
    tier-aware bottleneck-link cost path) with tier_escalation, plus the
    factorized fleet-size sweep (1k / 10k / 100k devices)."""
    report = measure()
    report["multi_tier"] = measure(
        scheme="tier_escalation", scenario="multi_tier", latency_budget=4.0
    )
    report["fleet_sweep"] = fleet_sweep()
    return report


def _check_section(results: dict, base_results: dict, label: str) -> list:
    failures = []
    for B, row in base_results.items():
        got = results.get(B)
        if got is None:
            failures.append(f"{label} B={B}: missing from report")
            continue
        floor = row["speedup"] / REGRESSION_FACTOR
        if got["speedup"] < floor:
            failures.append(
                f"{label} B={B}: batched/scalar speedup {got['speedup']:.2f}x "
                f"< {floor:.2f}x (baseline {row['speedup']:.2f}x / "
                f"{REGRESSION_FACTOR})"
            )
    return failures


def _check_sweep(report: dict, baseline: dict) -> list:
    """Gate the fleet-size sweep: every baseline fleet size must be present
    (the sweep itself raises if a dense (D, D) matrix is materialized, so a
    point existing means the factorized path carried it), and the
    throughput-scaling ratio pps@smallest / pps@largest must not blow up
    more than SWEEP_REGRESSION_FACTOR vs the committed baseline."""
    failures = []
    base_fs = baseline["fleet_sweep"]["results"]
    got_fs = report.get("fleet_sweep", {}).get("results", {})
    for D in base_fs:
        if D not in got_fs or got_fs[D]["pps"] <= 0:
            failures.append(f"fleet_sweep D={D}: missing from report")
    if failures:
        return failures
    lo, hi = min(base_fs, key=int), max(base_fs, key=int)
    base_ratio = base_fs[lo]["pps"] / base_fs[hi]["pps"]
    got_ratio = got_fs[lo]["pps"] / got_fs[hi]["pps"]
    if got_ratio > base_ratio * SWEEP_REGRESSION_FACTOR:
        failures.append(
            f"fleet_sweep: pps@{lo}/pps@{hi} scaling ratio {got_ratio:.1f} "
            f"> {base_ratio:.1f} (baseline) x {SWEEP_REGRESSION_FACTOR} — "
            "placement cost is growing with raw fleet size again"
        )
    return failures


def check(report: dict, baseline_path: str) -> int:
    """Fail on a >2x regression of the batched-vs-scalar SPEEDUP ratio (mix
    fleet and, when the baseline records it, the multi-tier fleet) or a
    fleet-sweep failure (see :func:`_check_sweep`).

    The gates compare ratios, not absolute placements/sec: everything runs
    on the same machine in the same job, so ratios are portable across
    runner hardware while absolute throughput is not.
    """
    with open(baseline_path) as f:
        baseline = json.load(f)
    failures = _check_section(report["results"], baseline["results"], "mix")
    if "multi_tier" in baseline:
        failures += _check_section(
            report.get("multi_tier", {}).get("results", {}),
            baseline["multi_tier"]["results"],
            "multi_tier",
        )
    if "fleet_sweep" in baseline:
        failures += _check_sweep(report, baseline)
    for msg in failures:
        print(f"REGRESSION {msg}", file=sys.stderr)
    return 1 if failures else 0


def run(ctx) -> None:
    """benchmarks.run entry point: emit CSV rows + write BENCH_place.json."""
    report = full_report()
    for B, row in report["results"].items():
        ctx.emit(f"place_scalar_pps_B{B}", row["scalar_pps"])
        ctx.emit(f"place_batched_pps_B{B}", row["batched_pps"])
        ctx.emit(f"place_speedup_B{B}", row["speedup"])
    for B, row in report["multi_tier"]["results"].items():
        ctx.emit(f"place_mt_batched_pps_B{B}", row["batched_pps"])
        ctx.emit(f"place_mt_speedup_B{B}", row["speedup"])
    for D, row in report["fleet_sweep"]["results"].items():
        ctx.emit(f"place_fleet_pps_D{D}", row["pps"])
    from .common import write_current_run

    write_current_run("place", report)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_place.json")
    ap.add_argument("--check", default=None,
                    help="baseline json; exit 1 on >2x throughput regression")
    args = ap.parse_args()
    report = full_report()
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    for label, section in (("mix/ibdash", report),
                           ("multi_tier/tier_escalation", report["multi_tier"])):
        for B, row in section["results"].items():
            print(f"{label:26s} B={B:>5s}  "
                  f"scalar {row['scalar_pps']:10.1f} pl/s  "
                  f"batched {row['batched_pps']:10.1f} pl/s  "
                  f"speedup {row['speedup']:6.2f}x")
    for D, row in report["fleet_sweep"]["results"].items():
        print(f"{'fleet_sweep/ibdash':26s} D={D:>6s}  "
              f"batched {row['pps']:10.1f} pl/s  "
              f"wave {row['wave_s'] * 1e3:8.1f} ms")
    if args.check:
        sys.exit(check(report, args.check))


if __name__ == "__main__":
    main()
