"""Beyond-paper: weight-stationary (TP) serving sharding vs FSDP baseline —
collective-byte reduction per decode/prefill cell (from the dry-run grid)."""
import json
import os


def run(ctx):
    path = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json")
    if not os.path.exists(path):
        ctx.emit("serving_shard_skipped", 0, "dryrun_results.json missing")
        return
    with open(path) as f:
        results = json.load(f)
    gains = []
    for key, rec in sorted(results.items()):
        if rec.get("status") != "ok" or rec.get("mesh") != "single":
            continue
        if rec["shape"] not in ("decode_32k", "prefill_32k", "long_500k"):
            continue
        if rec.get("variant", {}).get("infer_shard") != "tp":
            continue
        base_key = f"{rec['arch']}|{rec['shape']}|single|remat=block"
        base = results.get(base_key)
        if not base or base.get("status") != "ok":
            continue
        b = base["collectives"]["total_bytes"]
        t = rec["collectives"]["total_bytes"]
        gain = b / max(t, 1.0)
        gains.append(gain)
        ctx.emit(f"tp_coll_gain_{rec['arch']}_{rec['shape']}", gain,
                 f"{b:.2e} -> {t:.2e} B/step")
    if gains:
        import numpy as np
        ctx.emit("tp_coll_gain_geomean", float(np.exp(np.mean(np.log(gains)))),
                 f"over {len(gains)} serving cells")
