"""Always-on streaming service: offered load vs shed rate and tail latency.

Sweeps the open-loop Poisson stream over the mixed 100-device fleet at two
offered-load points (the service's admission queue + wave cap throttle
dispatch to roughly the fleet's sustainable rate):

  * ``moderate`` — comfortably inside fleet capacity: nothing is shed and
    the ``latency_critical`` p99 sits far under its SLO;
  * ``overload`` — well past capacity (>= 10k instances), run twice:
      - with admission: deadline-aware shedding + best_effort backpressure
        keep the critical p99 INSIDE its SLO;
      - the no-admission baseline (unbounded queue, shedding off): every
        instance executes and the critical p99 blows past the SLO — the
        run that motivates the subsystem.

Also gates arrival generation throughput (>= 10k instances/sec: the
generators are vectorised and lazy about DAG construction) and fused
placement throughput (wall-clock, generous factor).

Writes ``BENCH_stream.json``; ``--check BASELINE.json`` exits non-zero when
any acceptance gate fails or shed-rate / tail-latency columns drift from
the committed baseline (the run is seeded, so shed counts are
deterministic — the tolerance only covers library drift).

    PYTHONPATH=src python -m benchmarks.bench_stream \\
        [--out BENCH_stream.json] [--check benchmarks/BENCH_stream.baseline.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

N_DEVICES = 100
HORIZON = 45.0
MODERATE_RATE = 60.0
OVERLOAD_RATE = 240.0
QUEUE_CAP = 256
WAVE_CAP = 30                  # per 0.25 s tick -> ~120 dispatches/sec
TICK = 0.25
SLO_CRITICAL = 6.0
SLO_BEST_EFFORT = 30.0

GEN_FLOOR = 10_000             # arrival-generation instances/sec
SHED_TOLERANCE = 0.05          # |shed_rate - baseline| slack
P99_FACTOR = 1.5               # per-column p99 drift factor vs baseline
THROUGHPUT_FACTOR = 3.0        # placements/sec wall-clock regression factor


def _streams():
    from repro.stream import default_streams

    return default_streams(
        slo_critical=SLO_CRITICAL, slo_best_effort=SLO_BEST_EFFORT
    )


def measure_generation() -> dict:
    """Arrival-process throughput: vectorised generation, lazy DAGs."""
    from repro.stream import diurnal_arrivals, poisson_arrivals

    streams = _streams()
    t0 = time.perf_counter()
    arr = poisson_arrivals(streams, 2000.0, 100.0, seed=3)
    arr += diurnal_arrivals(streams, 500.0, 3000.0, 100.0, seed=4)
    dt = time.perf_counter() - t0
    return {"n": len(arr), "gen_per_sec": len(arr) / dt}


def measure(profile, rate: float, admission: bool) -> dict:
    from repro.api import Orchestrator, make_cluster, make_policy
    from repro.stream import AdmissionConfig, StreamingOrchestrator
    from repro.stream import poisson_arrivals

    cluster = make_cluster(
        profile, scenario="stream", n_devices=N_DEVICES, seed=0,
        horizon=HORIZON * 6.0 + 120.0,      # baseline backlog drains late
    )
    orch = Orchestrator(
        cluster,
        make_policy("ibdash", alpha=0.5, beta=0.1, gamma=3,
                    lats_model=profile.lats_model),
    )
    arrivals = poisson_arrivals(_streams(), rate, HORIZON, seed=7)
    service = StreamingOrchestrator(
        orch,
        admission=AdmissionConfig(queue_cap=QUEUE_CAP) if admission else None,
        wave_cap=WAVE_CAP if admission else None,
        tick=TICK,
    )
    t0 = time.perf_counter()
    res = service.run(arrivals)
    wall = time.perf_counter() - t0
    c = res.metrics["counters"]
    return {
        "rate": rate,
        "admission": admission,
        "n_arrivals": res.n_arrivals,
        "shed_rate": res.shed_rate,
        "shed": res.stats["shed"],
        "completed": res.stats["completed"],
        "lost": res.stats["lost"],
        "deadline_missed": c.get("deadline_missed", 0),
        "deadline_missed_critical": c.get("deadline_missed_latency_critical", 0),
        "p50_critical": res.p("p50", "latency_critical"),
        "p99_critical": res.p("p99", "latency_critical"),
        "p999_critical": res.p("p999", "latency_critical"),
        "p99_best_effort": res.p("p99", "best_effort"),
        "placements_per_sec": res.metrics["gauges"]["placements_per_sec"],
        "wall_s": wall,
    }


def full_report() -> dict:
    from repro.api import make_profile

    profile = make_profile(seed=0)
    return {
        "config": {
            "n_devices": N_DEVICES, "horizon": HORIZON,
            "moderate_rate": MODERATE_RATE, "overload_rate": OVERLOAD_RATE,
            "queue_cap": QUEUE_CAP, "wave_cap": WAVE_CAP, "tick": TICK,
            "slo_critical": SLO_CRITICAL, "slo_best_effort": SLO_BEST_EFFORT,
        },
        "generation": measure_generation(),
        "results": {
            "moderate": measure(profile, MODERATE_RATE, admission=True),
            "overload": measure(profile, OVERLOAD_RATE, admission=True),
            "overload_baseline": measure(
                profile, OVERLOAD_RATE, admission=False
            ),
        },
    }


def check(report: dict, baseline_path: str) -> int:
    """Gate the PR's acceptance properties against the committed baseline:

    * the overload point offers >= 10k instances and the moderate point is
      a genuinely distinct load level;
    * with admission, the latency_critical p99 stays inside its SLO at an
      offered load where the no-admission baseline violates it;
    * moderate load sheds (almost) nothing and also meets the SLO;
    * arrival generation sustains >= GEN_FLOOR instances/sec;
    * shed-rate and p99 columns stay within tolerance of the committed
      baseline, and fused placement throughput within THROUGHPUT_FACTOR.
    """
    with open(baseline_path) as f:
        baseline = json.load(f)
    failures = []
    res = report["results"]
    mod, over, base_run = (
        res["moderate"], res["overload"], res["overload_baseline"]
    )

    if over["n_arrivals"] < 10_000:
        failures.append(
            f"overload offered only {over['n_arrivals']} instances (< 10k)"
        )
    if over["rate"] <= mod["rate"]:
        failures.append("load points are not distinct")
    if over["p99_critical"] > SLO_CRITICAL:
        failures.append(
            f"overload+admission critical p99 {over['p99_critical']:.2f}s "
            f"> SLO {SLO_CRITICAL}s — shedding no longer protects criticals"
        )
    if base_run["p99_critical"] <= SLO_CRITICAL:
        failures.append(
            f"no-admission baseline critical p99 "
            f"{base_run['p99_critical']:.2f}s <= SLO {SLO_CRITICAL}s — the "
            "overload point no longer stresses the fleet"
        )
    if over["shed_rate"] <= 0.0:
        failures.append("overload+admission shed nothing")
    if base_run["shed_rate"] != 0.0:
        failures.append("the no-admission baseline shed instances")
    if mod["p99_critical"] > SLO_CRITICAL:
        failures.append(
            f"moderate critical p99 {mod['p99_critical']:.2f}s > SLO"
        )
    if mod["shed_rate"] > 0.02:
        failures.append(
            f"moderate load shed {100 * mod['shed_rate']:.1f}% (> 2%)"
        )
    gen = report["generation"]["gen_per_sec"]
    if gen < GEN_FLOOR:
        failures.append(
            f"arrival generation {gen:.0f}/s < {GEN_FLOOR}/s"
        )

    for key in ("moderate", "overload", "overload_baseline"):
        got, ref = res[key], baseline["results"][key]
        if abs(got["shed_rate"] - ref["shed_rate"]) > SHED_TOLERANCE:
            failures.append(
                f"{key}: shed rate {got['shed_rate']:.3f} drifted from "
                f"baseline {ref['shed_rate']:.3f} (> {SHED_TOLERANCE})"
            )
        if got["p99_critical"] > ref["p99_critical"] * P99_FACTOR:
            failures.append(
                f"{key}: critical p99 {got['p99_critical']:.2f}s > "
                f"baseline {ref['p99_critical']:.2f}s * {P99_FACTOR}"
            )
        base_tp = ref["placements_per_sec"]
        if base_tp > 0 and got["placements_per_sec"] < base_tp / THROUGHPUT_FACTOR:
            failures.append(
                f"{key}: {got['placements_per_sec']:.0f} placements/s < "
                f"{base_tp / THROUGHPUT_FACTOR:.0f} "
                f"(baseline {base_tp:.0f} / {THROUGHPUT_FACTOR})"
            )

    for msg in failures:
        print(f"REGRESSION {msg}", file=sys.stderr)
    return 1 if failures else 0


def run(ctx) -> None:
    """benchmarks.run entry point: emit CSV rows + write BENCH_stream.json."""
    report = full_report()
    for key, row in report["results"].items():
        ctx.emit(f"stream_{key}_shed_rate", row["shed_rate"])
        ctx.emit(f"stream_{key}_p99_critical", row["p99_critical"])
        ctx.emit(f"stream_{key}_p99_best_effort", row["p99_best_effort"])
    ctx.emit("stream_gen_per_sec", report["generation"]["gen_per_sec"])
    from .common import write_current_run

    write_current_run("stream", report)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_stream.json")
    ap.add_argument("--check", default=None,
                    help="baseline json; exit 1 on an SLO/shed regression")
    args = ap.parse_args()
    report = full_report()
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    gen = report["generation"]
    print(f"generation {gen['gen_per_sec']:,.0f} arrivals/s ({gen['n']:,d})")
    for key, row in report["results"].items():
        print(
            f"{key:18s} rate {row['rate']:5.0f}/s  n {row['n_arrivals']:6d}  "
            f"shed {100 * row['shed_rate']:5.1f}%  "
            f"p99crit {row['p99_critical']:6.2f}s  "
            f"p99best {row['p99_best_effort']:6.2f}s  "
            f"missed {row['deadline_missed']:4d}  "
            f"{row['placements_per_sec']:7.0f} placements/s  "
            f"wall {row['wall_s']:.1f}s"
        )
    if args.check:
        sys.exit(check(report, args.check))


if __name__ == "__main__":
    main()
