"""Fig. 8 — average service time: 6 schemes x 3 scenarios (+ headline claim)."""
import numpy as np

from .common import SCENARIOS, SCHEMES


def run(ctx):
    grid = ctx.grid()
    for scen in SCENARIOS:
        for scheme in SCHEMES:
            r = grid[(scheme, scen)]
            ctx.emit(f"fig8_service_{scen}_{scheme}", r.avg_service_time, "s")
    # headline: IBDASH vs best baseline (paper: -14 % avg)
    rels = []
    for scen in SCENARIOS:
        ib = grid[("ibdash", scen)].avg_service_time
        best = min(grid[(s, scen)].avg_service_time for s in SCHEMES if s != "ibdash")
        rels.append(1 - ib / best)
        ctx.emit(f"fig8_ibdash_vs_best_{scen}", 100 * (1 - ib / best),
                 "% service-time reduction")
    ctx.emit("fig8_ibdash_vs_best_avg", 100 * float(np.mean(rels)),
             "% avg reduction (paper: 14%)")
    # per-application split (paper plots each app separately)
    for scheme in ("ibdash", "lavea"):
        for app, (svc, _) in grid[(scheme, "mix")].per_app().items():
            ctx.emit(f"fig8_mix_{scheme}_{app}", svc, "s")
