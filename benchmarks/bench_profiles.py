"""Table III / Fig. 5 — device profiles + LaTS log-linear latency-CPU fit."""
import numpy as np


def run(ctx):
    prof = ctx.profile
    m = prof.interference
    # Fig. 5: is log(latency) ~ linear in CPU usage on each class?
    rng = np.random.default_rng(1)
    for p in range(m.n_classes):
        xs, ys = [], []
        for _ in range(300):
            counts = rng.poisson(rng.uniform(0.2, 2.5), m.n_types).astype(float)
            usage = min(float((prof.cpu_usage[p] * counts).sum()), 4.0)
            i = int(rng.integers(m.n_types))
            xs.append(usage)
            ys.append(np.log(m.estimate(p, i, counts) / m.base[p, i]))
        A = np.stack([np.asarray(xs), np.ones(len(xs))], 1)
        coef, res, *_ = np.linalg.lstsq(A, np.asarray(ys), rcond=None)
        ss_tot = float(((ys - np.mean(ys)) ** 2).sum())
        r2 = 1 - float(res[0]) / ss_tot if len(res) and ss_tot > 0 else 1.0
        name = prof.classes[p].name
        ctx.emit(f"fig5_loglat_vs_cpu_r2_{name}", r2, f"b={coef[0]:.3f}")
    # Table III sanity: fastest class has the smallest mean base latency
    means = m.base.mean(axis=1)
    ctx.emit("tab3_fastest_class_idx", int(np.argmin(means)),
             f"{prof.classes[int(np.argmin(means))].name}")
