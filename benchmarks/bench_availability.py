"""Fig. 7 / Table IV — exponential availability model validation."""
import numpy as np


def run(ctx):
    from repro.core.availability import (
        LAMBDA_MIX,
        availability,
        fit_failure_rate,
        young_daly_interval,
    )

    rng = np.random.default_rng(0)
    # sample synthetic "mobility traces" from Table-IV rates and check the
    # MLE recovers each lambda (the paper's Fig. 7b fit)
    errs = []
    for lam in (1.5e-4, 9e-4, 3.2e-5):
        lifetimes = rng.exponential(1 / lam, 800)
        lam_hat = fit_failure_rate(lifetimes, [False] * 800)
        errs.append(abs(lam_hat - lam) / lam)
    ctx.emit("fig7_lambda_mle_max_rel_err", float(max(errs)), "over 3 Table-IV rates")

    # availability curve values at the end of the paper's 300 s simulation
    for i, lam in enumerate(LAMBDA_MIX):
        ctx.emit(f"fig7_avail_300s_ED{i}", availability(float(lam), 300.0),
                 f"lambda={lam:.1e}")

    # derived production policy: Young/Daly for a 512-pod job
    lam_job = 512 * 1e-5
    ctx.emit("young_daly_512pods_30s_ckpt",
             young_daly_interval(lam_job, 30.0), "s between checkpoints")
