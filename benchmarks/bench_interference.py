"""Fig. 4 / Fig. 2 — interference linearity + additivity verification."""
import numpy as np


def run(ctx):
    m = ctx.profile.interference
    rng = np.random.default_rng(0)
    # linearity: every pair plot is exactly linear by construction; verify
    # the simulator's *measured* latencies reproduce it via the engine model
    max_lin_err = 0.0
    for _ in range(200):
        p = rng.integers(m.n_classes)
        i, j = rng.integers(m.n_types, size=2)
        plot = m.pair_plot(int(p), int(i), int(j), k_max=8)
        d = np.diff(plot)
        max_lin_err = max(max_lin_err, float(np.abs(d - d[0]).max()))
    ctx.emit("fig4_linearity_max_dev", max_lin_err, "s (0 = perfectly linear)")

    # additivity: f(i, a+b) == f(i,a) + f(i,b) - base  (paper's Fig. 4 claim)
    max_add_err = 0.0
    for _ in range(200):
        p = int(rng.integers(m.n_classes))
        i = int(rng.integers(m.n_types))
        ca = rng.poisson(1.0, m.n_types).astype(float)
        cb = rng.poisson(1.0, m.n_types).astype(float)
        lhs = m.estimate(p, i, ca + cb)
        rhs = m.estimate(p, i, ca) + m.estimate(p, i, cb) - m.base[p, i]
        max_add_err = max(max_add_err, abs(lhs - rhs))
    ctx.emit("fig4_additivity_max_err", max_add_err, "s (0 = perfectly additive)")

    # heterogeneity (Fig. 2a): slopes differ across task pairs
    spread = float(m.slope.std() / m.slope.mean())
    ctx.emit("fig2_slope_heterogeneity_cv", spread, "coef of variation of m[p,i,j]")
